"""Control-plane emulation, pinned end to end.

Load-bearing properties (ISSUE acceptance criteria):

* **Zero friction is the identity** -- with ``polling_interval=1``, zero
  delays, zero cooldown, and no warm-up, a control-plane-wrapped policy
  reproduces the bare policy bit-for-bit (golden fixtures in
  ``tests/data/golden_controlplane.json``), and the equivalence survives
  ``FleetRunner`` bucketing (padding does not change behavior).
* **Hysteresis and clamps hold** -- no scale event applies inside an
  active cooldown window; replica counts stay in
  ``[min_replicas, max_replicas]``; the assignment used at step ``t``
  never reflects observations newer than ``t - observation_delay``;
  warm-up downtime only affects consumers touched by the scale event
  (hypothesis properties with deterministic fixed-instance fallbacks).
* **Semantics cannot drift** -- a fixed-seed ``KEDA_LAG_REAL``
  trajectory (assignments, lag, SLO metrics) on the ``topic_lifecycle``
  masked family is pinned, on the direct and the fleet path.
* **Inconsistent knobs fail loudly** -- each bad combination raises a
  named ``ValueError`` before anything compiles.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp

from repro import api
from repro.fleet import FleetConfig, FleetRunner
from repro.lagsim import (ControlPlaneConfig, ControlPlaneState,
                          LagSimConfig, simulate_lag, slo_summary, sweep_lag,
                          wrap_policy)

DATA = os.path.join(os.path.dirname(__file__), "data")
CFG = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2)
ZF = ControlPlaneConfig()               # the zero-friction identity
TRACE_FIELDS = ("lag_total", "lag_max", "consumers", "migrations",
                "unreadable")


def _load(name):
    with open(os.path.join(DATA, name)) as f:
        return json.load(f)


def _with_cp(cfg, cp):
    return dataclasses.replace(cfg, control_plane=cp)


def _assert_traces_equal(a, b, msg=""):
    for f in TRACE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{msg}: {f}")


# ---------------------------------------------------------------------------
# named errors for inconsistent knobs (satellite bugfix)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kwargs,match", [
    ({"polling_interval": 0}, "polling_interval=0 must be >= 1"),
    ({"observation_delay": -1}, "observation_delay=-1 must be >= 0"),
    ({"actuation_delay": -2}, "actuation_delay=-2 must be >= 0"),
    ({"cooldown_period": -1}, "cooldown_period=-1 must be >= 0"),
    ({"polling_interval": 4, "cooldown_period": 2},
     "cooldown_period=2 < polling_interval=4"),
    ({"warmup_steps": -1}, "warmup_steps=-1 must be >= 0"),
    ({"min_replicas": 0}, "min_replicas=0 must be >= 1"),
    ({"min_replicas": 3, "max_replicas": 2},
     "max_replicas=2 < min_replicas=3"),
    ({"polling_interval": 1.5}, "must be an integer number of steps"),
    ({"min_replicas": True}, "must be an integer number of replicas"),
])
def test_named_config_errors(kwargs, match):
    with pytest.raises(ValueError, match=match):
        ControlPlaneConfig(**kwargs)


def test_cooldown_zero_and_equal_to_polling_are_legal():
    ControlPlaneConfig(polling_interval=4, cooldown_period=0)
    ControlPlaneConfig(polling_interval=4, cooldown_period=4)


def test_engine_rejects_non_config_control_plane():
    with pytest.raises(ValueError, match="must be a ControlPlaneConfig"):
        LagSimConfig(control_plane={"polling_interval": 2}).resolve(4)


def test_api_simulate_raises_named_errors():
    tr = np.full((1, 6, 4), 0.5, np.float32)
    with pytest.raises(ValueError, match="cooldown_period=2 < polling"):
        api.simulate(tr, policies=("BFD",),
                     control_plane={"polling_interval": 4,
                                    "cooldown_period": 2})
    with pytest.raises(ValueError, match="warmup_steps=-1"):
        api.simulate(tr, policies=("BFD",),
                     control_plane={"warmup_steps": -1})
    with pytest.raises(ValueError, match="must be a ControlPlaneConfig"):
        api.simulate(tr, policies=("BFD",), control_plane=3)


# ---------------------------------------------------------------------------
# zero-friction equivalence goldens
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pol", ("KEDA_LAG", "RATE_THRESHOLD"))
def test_zero_friction_golden(pol):
    """Wrapped-at-zero-friction and bare both reproduce the pinned
    trajectories exactly: the wrapper is the identity, and neither side
    can drift without the golden catching it."""
    g = _load("golden_controlplane.json")
    trace = jnp.asarray(g["trace"], jnp.float32)
    bare = simulate_lag(trace, policy=pol, cfg=CFG)
    wrapped = simulate_lag(trace, policy=pol, cfg=_with_cp(CFG, ZF))
    for r, which in ((bare, "bare"), (wrapped, "wrapped")):
        for f in TRACE_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(r, f)), np.asarray(g[pol][f]),
                err_msg=f"{pol} ({which}): {f}")


@pytest.mark.parametrize("pol", ("BFD", "MBFP", "ANNEAL_STICKY"))
def test_zero_friction_packers_bit_identical(pol):
    g = _load("golden_controlplane.json")
    trace = jnp.asarray(g["trace"], jnp.float32)
    _assert_traces_equal(simulate_lag(trace, policy=pol, cfg=CFG),
                         simulate_lag(trace, policy=pol,
                                      cfg=_with_cp(CFG, ZF)), pol)


def test_zero_friction_real_equals_plain_keda():
    """KEDA_LAG_REAL with zero-friction knob overrides degenerates to the
    idealized KEDA_LAG baseline bit-for-bit."""
    g = _load("golden_controlplane.json")
    trace = jnp.asarray(g["trace"], jnp.float32)
    real = simulate_lag(trace, policy="KEDA_LAG_REAL", cfg=_with_cp(CFG, ZF))
    for f in TRACE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(real, f)), np.asarray(g["KEDA_LAG"][f]),
            err_msg=f"KEDA_LAG_REAL(zero friction): {f}")


def test_zero_friction_under_fleet_bucketing():
    """Bucket padding does not change control-plane behavior: ragged
    zero-friction fleet runs equal the unwrapped fleet runs exactly."""
    rng = np.random.default_rng(11)
    scen = [jnp.asarray(rng.uniform(0, 1.1, s), jnp.float32)
            for s in ((14, 4), (20, 8), (9, 6))]
    pols = ("BFD", "KEDA_LAG", "KEDA_LAG_REAL")
    runner = FleetRunner(FleetConfig(t_buckets=(20,), n_buckets=(8,)))
    plain = runner.simulate(pols, scen, CFG)
    wrapped = runner.simulate(pols, scen, _with_cp(CFG, ZF))
    for i in range(len(scen)):
        # plain policies: the zero-friction wrapper is the identity
        # (REAL is excluded here -- without cfg.control_plane it runs
        # its own registered friction defaults, and the ZF run
        # overrides them to zero)
        for p in (0, 1):
            np.testing.assert_array_equal(plain.lag_total[i][p],
                                          wrapped.lag_total[i][p])
            np.testing.assert_array_equal(plain.consumers[i][p],
                                          wrapped.consumers[i][p])
            np.testing.assert_array_equal(plain.migrations[i][p],
                                          wrapped.migrations[i][p])
        # zero-friction REAL == idealized KEDA_LAG, under padding too
        np.testing.assert_array_equal(wrapped.consumers[i][2],
                                      wrapped.consumers[i][1])
        np.testing.assert_array_equal(wrapped.lag_total[i][2],
                                      wrapped.lag_total[i][1])


# ---------------------------------------------------------------------------
# properties: cooldown / clamping / staleness / warm-up locality
# ---------------------------------------------------------------------------
def _trace_from_seed(seed, t=40, n=6, scale=1.2):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, scale, (t, n)), jnp.float32)


def _apply_steps(assigns, consumers):
    """Steps at which a scale decision *applied* (output changed)."""
    assigns = np.asarray(assigns)
    consumers = np.asarray(consumers)
    events = []
    prev_a = np.full(assigns.shape[1], -1, assigns.dtype)
    prev_n = 0
    for t in range(assigns.shape[0]):
        if consumers[t] != prev_n or not np.array_equal(assigns[t], prev_a):
            events.append(t)
        prev_a, prev_n = assigns[t], consumers[t]
    return events


def _check_cooldown(seed, polling, cooldown, delay):
    cp = ControlPlaneConfig(polling_interval=polling,
                            cooldown_period=cooldown,
                            observation_delay=delay, actuation_delay=delay)
    trace = _trace_from_seed(seed)
    res, assigns = simulate_lag(trace, policy="KEDA_LAG",
                                cfg=_with_cp(CFG, cp), record_assign=True)
    events = _apply_steps(assigns, res.consumers)
    gaps = np.diff(events)
    assert (gaps >= max(cooldown, 1)).all(), (events, cp)
    # and decisions only ever apply actuation_delay after a poll step
    for t in events:
        assert (t - delay) % polling == 0, (t, cp)


def _check_clamp(seed, lo, hi):
    cp = ControlPlaneConfig(min_replicas=lo, max_replicas=hi,
                            polling_interval=2, cooldown_period=2,
                            warmup_steps=1)
    trace = _trace_from_seed(seed, scale=2.0)
    for pol in ("KEDA_LAG", "BFD"):
        res, assigns = simulate_lag(trace, policy=pol,
                                    cfg=_with_cp(CFG, cp),
                                    record_assign=True)
        cons = np.asarray(res.consumers)
        assert cons.min() >= lo and cons.max() <= hi, (pol, cons)
        # the assignment itself never names more than hi consumers
        a = np.asarray(assigns)
        for t in range(a.shape[0]):
            assert len(set(a[t][a[t] >= 0])) <= hi, (pol, t, a[t])


def _check_staleness(seed, delay):
    """The assignment at step t never reflects observations newer than
    t - observation_delay: editing the future leaves the prefix alone."""
    cp = ControlPlaneConfig(observation_delay=delay)
    # threshold high enough that the unamplified run stays well below the
    # max-consumer clip (a clipped scaler ignores the future trivially)
    cfgz = _with_cp(dataclasses.replace(CFG, lag_threshold=3.0), cp)
    t0 = 12
    tr1 = np.asarray(_trace_from_seed(seed))
    tr2 = tr1.copy()
    tr2[t0:] = tr2[t0:] * 5.0 + 1.0     # violently different future
    _, a1 = simulate_lag(jnp.asarray(tr1), policy="KEDA_LAG", cfg=cfgz,
                         record_assign=True)
    _, a2 = simulate_lag(jnp.asarray(tr2), policy="KEDA_LAG", cfg=cfgz,
                         record_assign=True)
    a1, a2 = np.asarray(a1), np.asarray(a2)
    np.testing.assert_array_equal(a1[:t0 + delay], a2[:t0 + delay])
    assert not np.array_equal(a1, a2)   # the future is not ignored


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), polling=st.integers(1, 4),
           cool=st.integers(0, 8), delay=st.integers(0, 3))
    def test_cooldown_property(seed, polling, cool, delay):
        if 0 < cool < polling:
            cool = polling              # keep the config consistent
        _check_cooldown(seed, polling, cool, delay)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), lo=st.integers(2, 3),
           span=st.integers(0, 3))
    def test_clamp_property(seed, lo, span):
        _check_clamp(seed, lo, lo + span)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), delay=st.integers(0, 4))
    def test_staleness_property(seed, delay):
        _check_staleness(seed, delay)


@pytest.mark.parametrize("seed", (0, 1))
def test_control_plane_properties_fixed_instances(seed):
    """Deterministic fallback of the hypothesis properties above (always
    runs, with or without hypothesis installed)."""
    _check_cooldown(seed, polling=3, cooldown=6, delay=1)
    _check_cooldown(seed + 10, polling=1, cooldown=0, delay=0)
    _check_clamp(seed, lo=2, hi=4)
    _check_staleness(seed, delay=2)
    _check_staleness(seed + 10, delay=0)


def test_warmup_touches_only_scaled_consumers():
    """Drive the wrapper directly with a scripted inner policy: the
    rebalance storm must hit exactly the consumers whose partition set
    the applied decision changed."""
    plan = {0: ([0, 0, 1, 1], 2)}        # tick -> (assignment, consumers)
    plan[3] = ([0, 0, 1, 2], 3)          # move p3 to a fresh consumer

    def inner_init(n):
        return jnp.int32(0)

    def inner_step(speeds, lag, prev, tick, active=None):
        later = [k for k in sorted(plan) if int(tick) >= k][-1]
        a, k = plan[later]
        return jnp.asarray(a, jnp.int32), jnp.int32(k), tick + 1

    init, step = wrap_policy(inner_init, inner_step,
                             ControlPlaneConfig(warmup_steps=4))
    n = 4
    speeds = jnp.full((n,), 0.5, jnp.float32)
    lag = jnp.zeros((n,), jnp.float32)
    prev = jnp.full((n,), -1, jnp.int32)
    state = init(n)
    assert isinstance(state, ControlPlaneState)
    seen = []
    for _ in range(6):
        prev, k, state = step(speeds, lag, prev, state)
        seen.append(np.asarray(state.warming).tolist())
    # t=0: group creation touches everyone; t=1,2 decay
    assert seen[0] == [4, 4, 4, 4]
    assert seen[1] == [3, 3, 3, 3] and seen[2] == [2, 2, 2, 2]
    # t=3: p3 moves consumer 1 -> 2; consumer 0 (p0, p1) is untouched
    assert seen[3] == [1, 1, 4, 4]
    assert seen[4] == [0, 0, 3, 3]


def test_warmup_storm_blocks_reads_in_engine():
    """A pure scale event (no partition moves) still costs downtime: the
    engine reports the warming partitions as unreadable and they drain
    nothing while the storm lasts."""
    g = _load("golden_controlplane.json")
    gold = g["topic_lifecycle"]["KEDA_LAG_REAL"]
    # pinned trajectory has storms with zero migrations: downtime that
    # only the control plane (not the migration model) can explain
    assert sum(gold["migrations"]) == 0
    assert max(gold["unreadable"]) > 0


# ---------------------------------------------------------------------------
# fixed-seed KEDA_LAG_REAL regression (direct + fleet path)
# ---------------------------------------------------------------------------
def test_keda_lag_real_topic_lifecycle_regression():
    g = _load("golden_controlplane.json")["topic_lifecycle"]
    sp = jnp.asarray(g["speeds"], jnp.float32)
    act = jnp.asarray(np.asarray(g["active"], bool))
    gold = g["KEDA_LAG_REAL"]
    res, assigns = simulate_lag(sp, policy="KEDA_LAG_REAL", cfg=CFG,
                                active=act, record_assign=True)
    np.testing.assert_array_equal(np.asarray(assigns),
                                  np.asarray(gold["assigns"]))
    for f in TRACE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(res, f)), np.asarray(gold[f]), err_msg=f)
    metrics = slo_summary(np.asarray(res.lag_total),
                          np.asarray(res.consumers),
                          np.asarray(res.migrations),
                          slo_lag=CFG.slo_lag_or_default, dt=CFG.dt)
    for k, v in gold["metrics"].items():
        assert float(metrics[k]) == pytest.approx(v, abs=1e-6), k


def test_keda_lag_real_regression_survives_fleet_padding():
    """The same pinned trajectory through FleetRunner with forced bucket
    padding (24x6 -> 32x8): control-plane semantics are padding-exact."""
    g = _load("golden_controlplane.json")["topic_lifecycle"]
    sp = jnp.asarray(g["speeds"], jnp.float32)
    act = jnp.asarray(np.asarray(g["active"], bool))
    gold = g["KEDA_LAG_REAL"]
    runner = FleetRunner(FleetConfig(t_buckets=(32,), n_buckets=(8,)))
    res = runner.simulate(("KEDA_LAG_REAL",), [(sp, act)], CFG)
    np.testing.assert_allclose(res.lag_total[0][0],
                               np.asarray(gold["lag_total"]), atol=1e-6)
    np.testing.assert_array_equal(res.consumers[0][0],
                                  np.asarray(gold["consumers"]))
    np.testing.assert_array_equal(res.migrations[0][0],
                                  np.asarray(gold["migrations"]))
    np.testing.assert_array_equal(res.unreadable[0][0],
                                  np.asarray(gold["unreadable"]))


# ---------------------------------------------------------------------------
# api threading
# ---------------------------------------------------------------------------
def test_api_simulate_threads_control_plane():
    tr = np.asarray(jax.random.uniform(jax.random.key(2), (2, 12, 5),
                                       maxval=0.8))
    knobs = {"polling_interval": 2, "cooldown_period": 4, "warmup_steps": 1}
    via_map = api.simulate(tr, policies=("BFD", "KEDA_LAG_REAL"),
                           control_plane=knobs)
    via_cfg = api.simulate(tr, policies=("BFD", "KEDA_LAG_REAL"),
                           control_plane=ControlPlaneConfig(**knobs))
    assert via_map.schema_version == api.API_VERSION
    np.testing.assert_array_equal(via_map.lag_total, via_cfg.lag_total)
    np.testing.assert_array_equal(via_map.consumers, via_cfg.consumers)
    # friction actually bites: the wrapped runs differ from frictionless
    plain = api.simulate(tr, policies=("BFD", "KEDA_LAG_REAL"))
    assert not np.array_equal(via_map.consumers, plain.consumers)


def test_api_exports_control_plane_config():
    assert api.ControlPlaneConfig is ControlPlaneConfig
    assert "ControlPlaneConfig" in api.__all__
    api.selfcheck()


def test_sweep_lag_accepts_control_plane():
    trace = _trace_from_seed(3, t=16, n=4)
    cp = ControlPlaneConfig(polling_interval=2, cooldown_period=2)
    res = sweep_lag(("KEDA_LAG", "CLOUD_RUN_CPU_LAG"), trace[None],
                    cfg=_with_cp(CFG, cp))
    assert np.asarray(res.lag_total).shape == (2, 1, 16)
