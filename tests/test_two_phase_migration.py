"""Two-phase migration invariant (paper Sec. V-C / Fig. 5).

During GROUP_MANAGEMENT a partition's ``start`` must never be sent to its
new consumer before the previous owner's ``stop`` is acknowledged -- at no
tick may two group members read one partition.  The broker would raise on
an actual double-assign; these tests additionally pin the *protocol
ordering* at the controller's send boundary, so a regression that relaxes
the hand-off is caught even if it happens to avoid a broker-visible
overlap.

Also covers the ``seed``/``rate_jitter`` contract of
``AutoscaleSimulation`` (the constructor seed drives producer jitter and
nothing else).
"""
import numpy as np

from repro.broker import TopicPartition
from repro.serving import AutoscaleSimulation

CAP = 1.0e6


def test_no_start_before_stop_ack_under_churn():
    """A churny walk forces many reassignments; every in-flight migration
    must hold the stop->ack->start ordering at every tick."""
    sim = AutoscaleSimulation(
        n_partitions=10,
        rate_fn=AutoscaleSimulation.random_walk_rates(10, CAP, delta=25,
                                                      seed=11),
        capacity=CAP, monitor_interval=5.0)
    ctl = sim.controller
    broker = sim.broker
    group = ctl.cfg.group
    starts_checked = 0
    orig_send = ctl._send

    def checked_send(cid, msg):
        nonlocal starts_checked
        if msg.get("type") == "start":
            for t, p in msg["partitions"]:
                tp = TopicPartition(t, int(p))
                holder = broker.reader_of(group, tp)
                # the partition must be free (stop acked / owner expelled)
                # or already held by the very consumer being started
                assert holder is None or holder == f"consumer-{cid}", (
                    f"start for {tp} sent to consumer {cid} while "
                    f"{holder!r} still reads it")
                starts_checked += 1
        orig_send(cid, msg)

    ctl._send = checked_send
    for _ in range(400):
        sim.tick(1.0)
        # every stop-phase in-flight entry: the old owner still holds the
        # partition and the new consumer was not started on it
        for tp, (phase, old, new) in ctl._inflight.items():
            holder = broker.reader_of(group, tp)
            if phase == "stop_sent":
                assert holder in (None, f"consumer-{old}"), (
                    f"{tp} read by {holder!r} while stop from {old} pending")
                assert holder != f"consumer-{new}"
    assert starts_checked > 0
    assert any(rec.moved for rec in ctl.migrations), (
        "workload produced no migrations; invariant never exercised")


def test_constructor_seed_drives_only_producer_jitter():
    """Same seed + jitter => identical worlds; different seed => different
    production; with jitter off, the seed is inert (documented contract)."""
    def make(seed, jitter):
        sim = AutoscaleSimulation(
            n_partitions=3,
            rate_fn=AutoscaleSimulation.constant_rates([0.3e6, 0.4e6, 0.2e6]),
            capacity=CAP, monitor_interval=5.0, seed=seed, rate_jitter=jitter)
        sim.run(seconds=60, dt=1.0)
        return sim

    a, b = make(1, 0.2), make(1, 0.2)
    assert a.produced_bytes == b.produced_bytes
    np.testing.assert_array_equal(np.asarray(a.metrics.lag_bytes),
                                  np.asarray(b.metrics.lag_bytes))
    c = make(2, 0.2)
    assert c.produced_bytes != a.produced_bytes
    # jitter disabled: seed has no effect at all
    d, e = make(3, 0.0), make(4, 0.0)
    assert d.produced_bytes == e.produced_bytes
