"""API-surface tests: ``repro.api.__all__`` matches the documented
surface (README "Public API"), the registry smoke passes, and the facade
verbs return the shared versioned result schema.

The CI workflow runs the same ``selfcheck()`` as a standalone step, so a
surface regression fails both locally and in CI.
"""
import os
import re

import pytest

import jax

from repro import api

README = os.path.join(os.path.dirname(__file__), "..", "README.md")


def test_selfcheck_passes():
    api.selfcheck()


def test_all_exports_resolve():
    for name in api.__all__:
        assert hasattr(api, name), name


def test_all_matches_documented_surface():
    """Every ``__all__`` export appears in the README "Public API" section
    (in backticks), and the section documents nothing the module does not
    export."""
    with open(README) as f:
        text = f.read()
    m = re.search(r"## Public API\n(.*?)(?:\n## |\Z)", text, re.S)
    assert m, "README.md must keep a '## Public API' section"
    documented = set(re.findall(r"`([A-Za-z_][A-Za-z0-9_]*)`", m.group(1)))
    exported = set(api.__all__)
    missing = exported - documented
    assert not missing, f"undocumented exports: {sorted(missing)}"


def test_registry_smoke_every_family_populated():
    for family in api.FAMILIES:
        assert api.list_policies(family=family), family
    for backend in api.BACKENDS:
        assert api.list_policies(backend=backend), backend


# ---------------------------------------------------------------------------
# facade verbs return the versioned schema
# ---------------------------------------------------------------------------
def test_pack_outcome_schema():
    out = api.pack({"a": 0.6, "b": 0.7}, 1.0, algorithm="BFD")
    assert out.schema_version == api.API_VERSION
    assert out.n_bins == 2 and set(out.assignment) == {"a", "b"}
    assert out.rscore is None
    moved = api.pack({"a": 0.6, "b": 0.7}, 1.0, algorithm="BFD",
                     prev={"a": 1, "b": 0})
    assert moved.rscore is not None


def test_pack_backends_agree():
    speeds = [0.6, 0.7, 0.2, 0.4]
    py = api.pack({j: w for j, w in enumerate(speeds)}, 1.0,
                  algorithm="MBFP")
    jx = api.pack(speeds, 1.0, algorithm="MBFP", backend="jax")
    assert py.n_bins == jx.n_bins
    assert {int(k): v for k, v in py.assignment.items()} == jx.assignment


def test_sweep_outcome_schema():
    traces = jax.random.uniform(jax.random.key(0), (2, 6, 4), maxval=0.7)
    out = api.sweep(traces, 1.0, algorithms=("BFD", "MBFP"))
    assert out.schema_version == api.API_VERSION
    assert out.algorithms == ("BFD", "MBFP")
    assert out.bins.shape == out.rscores.shape == (2, 2, 6)


def test_simulate_outcome_schema():
    traces = jax.random.uniform(jax.random.key(1), (2, 8, 3), maxval=0.6)
    out = api.simulate(traces, policies=("BFD", "KEDA_LAG"),
                       migration_steps=1)
    assert out.schema_version == api.API_VERSION
    assert out.policies == ("BFD", "KEDA_LAG")
    assert out.lag_total.shape == (2, 2, 8)
    assert set(out.metrics) >= {"violation_frac", "peak_lag",
                                "consumer_seconds", "total_migrations"}
    assert all(v.shape == (2, 2) for v in out.metrics.values())


def test_optimize_outcome_schema():
    out = api.optimize([0.5, 0.6, 0.3], capacity=1.0, lambdas=(0.0, 2.0),
                       restarts=2, steps=40, score_heuristics=("BFD",))
    assert out.schema_version == api.API_VERSION
    assert out.front and out.hypervolume > 0
    assert set(out.heuristics) == {"BFD"}


def test_evaluate_outcome_schema():
    out = api.evaluate(algorithms=("BFD", "NFD"), deltas=(5,),
                       n_partitions=6, n_measurements=12)
    assert out.schema_version == api.API_VERSION
    assert set(out.cbs[5]) == {"BFD", "NFD"}
    assert out.pareto[5]                  # front never empty


def test_bench_report_shared_schema(tmp_path):
    import json

    rep = api.BenchReport(kind="unit", config={"n": 1},
                          families={"f": {"x": 1.0}},
                          extra={"timing": {"s": 0.1}})
    path = tmp_path / "BENCH_unit.json"
    out = rep.write(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == out
    assert on_disk["schema_version"] == api.API_VERSION
    assert on_disk["kind"] == "unit"
    assert on_disk["families"] == {"f": {"x": 1.0}}
    assert on_disk["timing"] == {"s": 0.1}


def test_bench_report_rejects_shadowed_envelope_keys():
    rep = api.BenchReport(kind="unit", config={}, families={},
                          extra={"config": {"shadow": True}})
    with pytest.raises(ValueError, match="must not shadow"):
        rep.as_dict()


def test_repo_bench_artifacts_share_schema():
    """The checked-in BENCH_*.json artifacts carry the shared envelope."""
    import json

    root = os.path.join(os.path.dirname(__file__), "..")
    found = [f for f in os.listdir(root)
             if f.startswith("BENCH_") and f.endswith(".json")]
    stale = []
    for f in found:
        with open(os.path.join(root, f)) as fh:
            data = json.load(fh)
        if "schema_version" not in data:
            stale.append(f)         # pre-schema artifact; check the rest
            continue
        assert data["kind"] and isinstance(data["families"], dict), f
    if stale and len(stale) == len(found):
        pytest.skip(f"{stale} predate the shared schema (regenerate via "
                    f"benchmarks/run.py)")
