"""Hybrid (jamba) decode consistency: stepping token-by-token through the
mixed attention/Mamba/MoE stack must reproduce the full-sequence forward
logits -- exercises the Mamba conv-context carry, SSM state updates, the
per-period KV cache, and MoE decode regrouping in one assertion."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import forward, init_decode_state, init_params, serve_step
from repro.models.layers import embed_inputs, logits_fn
from repro.models.transformer import backbone


def test_jamba_decode_matches_forward():
    # capacity_factor high enough that no token drops on either path:
    # capacity-based dropping differs between teacher-forced forward
    # (group = the whole sequence) and decode (group = regrouped batch) by
    # construction, so exact equivalence is only defined in the no-drop
    # regime (standard for capacity MoE).
    cfg = dataclasses.replace(configs.get("jamba-v0.1-52b", smoke=True),
                              dtype="float32", param_dtype="float32",
                              mamba_chunk=4, capacity_factor=8.0)
    params = init_params(jax.random.key(0), cfg)
    n_tok = 6
    toks = jax.random.randint(jax.random.key(1), (2, n_tok), 0, cfg.vocab_size)

    pos = jnp.broadcast_to(jnp.arange(n_tok)[None], (2, n_tok))
    h, _ = backbone(params, cfg, embed_inputs(params["embedding"], cfg, toks),
                    pos)
    full_logits = np.asarray(logits_fn(params, cfg, h), np.float32)

    state = init_decode_state(cfg, 2, 8)
    for t in range(n_tok):
        lg, state = serve_step(params, cfg, state, {"inputs": toks[:, t]})
        np.testing.assert_allclose(
            np.asarray(lg, np.float32), full_logits[:, t],
            atol=5e-2, rtol=5e-2,
            err_msg=f"jamba decode diverges from forward at step {t}")
    assert int(state["cache_len"]) == n_tok
