"""Adversarial search tests (``repro.scenarios.genome`` / ``.search``).

Load-bearing properties (ISSUE acceptance criteria):

* genomes decode/repair inside the registered knob bounds, and the
  ordered-pair constraint (death >= birth) is enforced in-graph;
* a fixed seed makes the whole search bit-deterministic -- same witness
  genome, same fitness, same history -- and the checked-in golden
  fixture (``tests/data/golden_adversarial.json``) pins it across
  sessions;
* the evolutionary loop strictly beats uniform random search at the
  same fitness-oracle eval budget (the bench ``--smoke`` asserts this
  for >= 2 policy families; here one representative keeps CI cheap);
* the witness replays: ``api.attack``'s worst genome, materialized as a
  trace and pushed through ``api.replay``, reproduces the fleet run of
  the same arrays bit for bit;
* ``FleetRunner.fitness`` refuses an incident-weighted objective when
  alerting is off (silent zeros would corrupt the search).
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.fleet import FleetRunner
from repro.lagsim import LagSimConfig
from repro.scenarios import (SearchConfig, attack, default_genome,
                             family_representatives, genome_bounds,
                             random_population, random_search,
                             repair_genome)
from repro.core.scenarios import family_spec

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_adversarial.json")

#: tiny but non-trivial search used across these tests (matches the
#: golden fixture's config)
TINY = SearchConfig(pop_size=6, generations=3, iters=48, n=5)


# ---------------------------------------------------------------------------
# genomes
# ---------------------------------------------------------------------------
def test_genome_bounds_and_default():
    spec = family_spec("adversarial")
    lo, hi = genome_bounds(spec)
    g = default_genome(spec)
    assert lo.shape == hi.shape == g.shape == (len(spec.knobs),)
    assert bool(jnp.all((g >= lo) & (g <= hi)))


def test_repair_clips_and_orders():
    spec = family_spec("adversarial")
    lo, hi = genome_bounds(spec)
    names = list(spec.knob_names)
    bi, di = names.index("birth_frac"), names.index("death_frac")
    raw = jnp.asarray(hi) + 1.0                  # everything out of bounds
    raw = raw.at[bi].set(0.9).at[di].set(0.1)    # death precedes birth
    fixed = repair_genome(spec, raw)
    assert bool(jnp.all((fixed >= lo) & (fixed <= hi)))
    assert float(fixed[di]) >= float(fixed[bi])


def test_random_population_in_bounds_and_deterministic():
    spec = family_spec("adversarial")
    lo, hi = genome_bounds(spec)
    a = random_population(spec, jax.random.PRNGKey(7), 16)
    b = random_population(spec, jax.random.PRNGKey(7), 16)
    assert a.shape == (16, len(spec.knobs))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert bool(jnp.all((a >= lo) & (a <= hi)))


# ---------------------------------------------------------------------------
# fixed-seed determinism + the golden witness
# ---------------------------------------------------------------------------
def test_attack_fixed_seed_deterministic():
    runner = FleetRunner()
    a = attack("NF", config=TINY, seed=3, runner=runner)
    b = attack("NF", config=TINY, seed=3, runner=runner)
    np.testing.assert_array_equal(a.best_genome, b.best_genome)
    assert a.best_fitness == b.best_fitness
    assert a.history == b.history
    c = attack("NF", config=TINY, seed=4, runner=runner)
    assert not np.array_equal(a.best_genome, c.best_genome) or \
        a.best_fitness != c.best_fitness


def test_golden_witness_fixture():
    with open(GOLDEN) as f:
        doc = json.load(f)
    cfg = SearchConfig(**doc["config"])
    res = attack("NF", config=cfg, seed=doc["result"]["seed"])
    assert res.as_dict() == doc["result"], (
        "the fixed-seed adversarial search no longer reproduces the "
        "checked-in golden witness; if the search algorithm changed "
        "intentionally, regenerate tests/data/golden_adversarial.json")


def test_evolution_beats_random_at_equal_evals():
    runner = FleetRunner()
    cfg = SearchConfig(pop_size=8, generations=5, iters=96, n=6)
    ev = attack("NF", config=cfg, seed=0, runner=runner)
    rs = random_search("NF", config=cfg, seed=0, runner=runner,
                       evals=ev.evals)
    assert rs.evals == ev.evals
    assert ev.best_fitness > rs.best_fitness


def test_early_stopping_bounds_evals():
    res = attack("NF", config=SearchConfig(pop_size=4, generations=64,
                                           iters=16, n=4, patience=2),
                 seed=0)
    assert res.generations_run < 64
    assert res.evals == res.generations_run * 4
    assert len(res.history) == res.generations_run


# ---------------------------------------------------------------------------
# fitness oracle
# ---------------------------------------------------------------------------
def test_fitness_requires_alerts_for_incident_weight():
    tr = jax.random.uniform(jax.random.key(0), (2, 8, 4), maxval=0.5)
    with pytest.raises(ValueError, match="alert"):
        FleetRunner().fitness(["NF"], tr, LagSimConfig(),
                              incident_weight=0.1)


def test_fitness_matches_summarize():
    tr = jax.random.uniform(jax.random.key(1), (3, 16, 5), maxval=1.2)
    runner = FleetRunner()
    cfg = LagSimConfig()
    fb = runner.fitness(["NF", "MWF"], tr, cfg)
    res = runner.simulate(("NF", "MWF"), tr, cfg)
    vf = np.asarray(res.summarize(cfg)["violation_frac"], np.float32)
    np.testing.assert_array_equal(fb.violation_frac, vf)
    np.testing.assert_array_equal(fb.fitness, vf)   # weight 0 => identity
    np.testing.assert_array_equal(fb.incidents, np.zeros_like(vf))


# ---------------------------------------------------------------------------
# the witness replays through the public API
# ---------------------------------------------------------------------------
def test_api_attack_witness_replays_bitexact(tmp_path):
    out = api.attack("NF", config=TINY, seed=0, baseline=False)
    assert out.witness_genome and out.witness_knobs
    tr = out.search.witness_trace(TINY, seed=0, batch=2)
    path = str(tmp_path / "witness.npz")
    from repro.scenarios import save_trace

    save_trace(tr, path)
    rp = api.replay(path, policies=("NF",))
    direct = api.simulate(tr.rates, policies=("NF",), active=tr.active,
                          capacity=tr.capacity)
    assert rp.result is not None
    np.testing.assert_array_equal(np.asarray(rp.result.lag_total),
                                  np.asarray(direct.lag_total))
    np.testing.assert_array_equal(
        np.asarray(rp.metrics["violation_frac"]),
        np.asarray(direct.metrics["violation_frac"]))
    assert rp.source == "adversarial:NF"


def test_api_attack_reports_baseline():
    out = api.attack("NF", config=TINY, seed=0, baseline=True)
    assert out.baseline is not None
    assert out.baseline_fitness == out.baseline.best_fitness
    assert out.beats_baseline == (out.best_fitness > out.baseline_fitness)


def test_family_representatives_cover_registry():
    reps = family_representatives()
    from repro.registry import get_spec, list_policies

    fams = {get_spec(p, backend="jax").family
            for p in list_policies(backend="jax")}
    assert set(reps) == fams
    for fam, pol in reps.items():
        assert get_spec(pol, backend="jax").family == fam
