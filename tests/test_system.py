"""End-to-end behaviour of the paper's system: the three components
(monitor -> controller -> consumers) assembled exactly as Fig. 3, checked
against the paper's own operating claims."""
import numpy as np

from repro.broker import Broker, SimClock, TopicPartition
from repro.core.controller import (CONTROLLER_INBOX, Controller,
                                   ControllerConfig, ControllerState,
                                   consumer_mailbox, state_diff)
from repro.core.monitor import Monitor, read_latest_measurement
from repro.serving import AutoscaleSimulation


def test_monitor_sliding_window_write_speed():
    """Sec. V-A: speed = (latest - earliest size) / window span over 30 s."""
    clock = SimClock()
    broker = Broker(clock)
    broker.create_topic("t", 1)
    mon = Monitor(broker, ["t"], window_secs=30.0)
    tp = TopicPartition("t", 0)
    for step in range(12):                     # 60 s at 1000 B/s, sampled 5 s
        for _ in range(5):
            broker.produce(tp, None, nbytes=1000)
        clock.advance(5.0)
        m = mon.sample()
    # after a full window the estimate converges to the true 1000 B/s
    assert abs(m.speeds[tp] - 1000.0) < 50.0
    # monitor publishes to monitor.writeSpeed; controller-side read works
    m2 = read_latest_measurement(broker)
    assert m2 is not None and abs(m2.speeds[tp] - m.speeds[tp]) < 1e-9


def test_state_diff_encodes_all_four_transitions():
    """Sec. V-C: the diff encodes creates / stops / starts / deletes."""
    tp = lambda i: TopicPartition("t", i)
    current = {tp(0): 0, tp(1): 0, tp(2): 1}
    desired = {tp(0): 0, tp(1): 2, tp(2): 2}
    diff = state_diff(current, desired, live_consumers={0, 1})
    assert diff.to_create == [2]
    assert diff.to_stop == {0: [tp(1)], 1: [tp(2)]}
    assert diff.to_start == {2: [tp(1), tp(2)]}
    assert diff.to_delete == [1]


def test_mailbox_partition_mapping():
    """Fig. 3: partition 0 is the controller inbox; consumer N uses N+1 --
    every byte a component reads is addressed to it."""
    assert CONTROLLER_INBOX.partition == 0
    assert consumer_mailbox(0).partition == 1
    assert consumer_mailbox(7).partition == 8


def test_consumption_rate_guarantee_vs_static_fleet():
    """The paper's headline: the autoscaler guarantees consumption >=
    production where a static undersized fleet cannot."""
    rates = [0.4e6] * 6                              # 2.4 MB/s total
    sim = AutoscaleSimulation(
        n_partitions=6, rate_fn=AutoscaleSimulation.constant_rates(rates),
        capacity=1.0e6)
    m = sim.run(seconds=300)
    lag = np.asarray(m.lag_bytes, float)
    # autoscaled: lag plateaus (slope ~0 in the last third)
    third = len(lag) // 3
    slope = (lag[-1] - lag[-third]) / third
    assert slope < 0.05e6, f"autoscaled lag still growing at {slope:.0f} B/s"
    assert sim.manager.n_alive() >= 3               # needs >= ceil(2.4/1.0)

    # static fleet of 1 consumer (no controller): lag grows linearly
    clock = SimClock()
    broker = Broker(clock)
    broker.create_topic("sensors", 6)
    broker.create_topic("consumer.metadata", 2)
    from repro.serving.replica import Replica, ReplicaConfig, Sink
    rep = Replica(0, broker, Sink(), ReplicaConfig(rate=1.0e6))
    for i in range(6):
        rep.handle.assign(TopicPartition("sensors", i))
    produced = 0
    for t in range(300):
        for i in range(6):
            for _ in range(int(0.4e6 // 4096)):
                broker.produce(TopicPartition("sensors", i), None, nbytes=4096)
                produced += 4096
        clock.advance(1.0)
        rep.step(1.0)
    static_lag = broker.total_lag("autoscaler", "sensors")
    assert static_lag > 100e6, "static fleet should fall behind"


def test_operational_cost_tracks_load():
    """Lower operational cost: fleet size follows total load down."""
    sim = AutoscaleSimulation(
        n_partitions=8,
        rate_fn=AutoscaleSimulation.constant_rates([0.9e6] * 8),
        capacity=1.0e6)
    sim.run(seconds=200)
    peak = sim.manager.n_alive()
    assert peak >= 7                                  # ~7.2 MB/s total
    sim.rate_fn = AutoscaleSimulation.constant_rates([0.1e6] * 8)
    sim.run(seconds=400)
    assert sim.manager.n_alive() <= max(2, peak // 3)
