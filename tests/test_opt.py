"""Global packing optimizer tests (ISSUE acceptance criteria):

* the branch-and-bound oracle is proven exact against brute-force set
  partition enumeration on exhaustive small instances (N <= 8), across
  uniform, quantized, near-half and zero/oversized weight mixes;
* the batched annealer (lambda = 0) reaches the oracle's bin count on
  those instances, and every state it returns is capacity-feasible;
* move deltas (the kernel's contract) equal exact cost recomputation for
  every (partition, target-bin) move;
* the Pareto / hypervolume reductions are pinned on hand instances;
* the ANNEAL / ANNEAL_STICKY policies run inside the closed-loop twin.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.binpack import CLASSICAL
from repro.opt import (
    anneal_chains,
    anneal_frontier,
    anneal_pack,
    assignment_cost,
    branch_and_bound,
    brute_force,
    dominated,
    hypervolume_2d,
    lower_bound_l1,
    lower_bound_l2,
    name_universe,
    optimality_gap,
    pareto_front,
)

C = 1.0


def _instances(max_n=8, trials=40, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for t in range(trials):
        n = int(rng.integers(1, max_n + 1))
        kind = t % 4
        if kind == 0:
            ws = rng.uniform(0, 1, n)
        elif kind == 1:               # quantized like the stream tests
            ws = rng.integers(0, 2049, n) / 1024.0
        elif kind == 2:               # near-half items stress L2 / symmetry
            ws = rng.uniform(0.4, 0.6, n)
        else:                         # zeros and oversized in the mix
            ws = rng.choice([0.0, 0.25, 0.5, 0.75, 1.0, 1.5], n)
        out.append(ws.astype(np.float64))
    return out


# ---------------------------------------------------------------------------
# branch-and-bound oracle vs brute force
# ---------------------------------------------------------------------------
def test_bnb_exact_vs_brute_force_small_n():
    for ws in _instances():
        want = brute_force(ws, C)
        res = branch_and_bound(ws.tolist(), C)
        assert res.optimal, ws
        assert res.n_bins == want, (ws, res.n_bins, want)
        assert res.lower_bound <= res.n_bins


def test_bnb_assignment_is_feasible_and_counts_bins():
    for ws in _instances(trials=20, seed=1):
        res = branch_and_bound(ws.tolist(), C)
        loads, counts = {}, {}
        for i, w in enumerate(ws):
            b = res.assignment[i]
            loads[b] = loads.get(b, 0.0) + w
            counts[b] = counts.get(b, 0) + 1
        for b, load in loads.items():
            assert load <= C + 1e-6 or counts[b] == 1, (ws, res.assignment)
        assert len(loads) == res.n_bins


def test_lower_bounds_sound_and_ordered():
    for ws in _instances(trials=30, seed=2):
        opt = branch_and_bound(ws.tolist(), C).n_bins
        l1 = lower_bound_l1(ws, C)
        l2 = lower_bound_l2(ws, C)
        assert l1 <= l2 <= opt, (ws, l1, l2, opt)


def test_bnb_known_instances():
    assert branch_and_bound([], C).n_bins == 0
    assert branch_and_bound([0.0, 0.0], C).n_bins == 1
    assert branch_and_bound([1.5], C).n_bins == 1        # oversized: own bin
    assert branch_and_bound([1.5, 0.0], C).n_bins == 2   # zero can't join it
    assert branch_and_bound([0.5] * 6, C).n_bins == 3
    assert branch_and_bound([0.6, 0.6, 0.4, 0.4], C).n_bins == 2
    # L2 sees what L1 misses: three items just over half
    assert lower_bound_l1([0.51] * 3, C) == 2
    assert lower_bound_l2([0.51] * 3, C) == 3


def test_heuristics_never_beat_the_oracle():
    for ws in _instances(trials=16, seed=3):
        opt = branch_and_bound(ws.tolist(), C).n_bins
        for name, algo in CLASSICAL.items():
            res = algo({i: w for i, w in enumerate(ws)}, C)
            assert res.n_bins >= opt, (name, ws)


# ---------------------------------------------------------------------------
# annealer vs the oracle
# ---------------------------------------------------------------------------
def test_anneal_matches_oracle_bin_count():
    """Acceptance bar: the stochastic optimizer at lambda = 0 reaches the
    proven optimum on the exhaustive small instances (fixed keys, so any
    failure is deterministic)."""
    rng = np.random.default_rng(4)
    for seed in range(6):
        n = int(rng.integers(3, 9))
        ws = rng.uniform(0, 1, n)
        opt = branch_and_bound(ws.tolist(), C).n_bins
        res = anneal_pack(jnp.asarray(ws, jnp.float32),
                          jnp.full(n, -1, jnp.int32), C,
                          jnp.zeros(24, jnp.float32),
                          jax.random.key(seed), steps=300)
        assert int(np.asarray(res.bins).min()) == opt, (seed, ws)


def test_anneal_states_always_feasible():
    rng = np.random.default_rng(5)
    n = 10
    ws = rng.uniform(0, 0.8, n)
    res = anneal_pack(jnp.asarray(ws, jnp.float32),
                      jnp.asarray(rng.integers(-1, 6, n), jnp.int32), C,
                      jnp.asarray([0.0, 1.0, 4.0, 16.0], jnp.float32),
                      jax.random.key(0), steps=200)
    assign = np.asarray(res.assign)
    m = name_universe(n)
    for k in range(assign.shape[0]):
        loads = np.zeros(m)
        counts = np.zeros(m, int)
        np.add.at(loads, assign[k], ws)
        np.add.at(counts, assign[k], 1)
        over = loads > C + 1e-5
        assert (counts[over] == 1).all(), (k, loads)
        assert int(res.bins[k]) == int((counts > 0).sum())


def test_anneal_optimizes_its_own_lambda():
    """Each chain must be at least as good *under its own lambda* as the
    best assignment found by any other lambda's chains -- the sweep's
    per-lambda winners are genuinely specialized."""
    rng = np.random.default_rng(6)
    n = 8
    ws = rng.uniform(0, 0.6, n)
    prev = rng.integers(0, 4, n)
    lam = jnp.repeat(jnp.asarray([0.0, 8.0], jnp.float32), 16)
    res = anneal_pack(jnp.asarray(ws, jnp.float32),
                      jnp.asarray(prev, jnp.int32), C, lam,
                      jax.random.key(1), steps=300)
    bins = np.asarray(res.bins, np.float64)
    rs = np.asarray(res.rscore, np.float64)
    best_lo = min(b + 0.0 * r for b, r in zip(bins[:16], rs[:16]))
    best_hi = min(b + 8.0 * r for b, r in zip(bins[16:], rs[16:]))
    cross_lo = min(b + 0.0 * r for b, r in zip(bins[16:], rs[16:]))
    cross_hi = min(b + 8.0 * r for b, r in zip(bins[:16], rs[:16]))
    assert best_lo <= cross_lo + 1e-6
    assert best_hi <= cross_hi + 1e-6


def test_move_delta_equals_exact_cost_recomputation():
    """The kernel contract: every unmasked delta equals the cost change of
    actually applying the move; every masked move is a no-op or
    infeasible."""
    from repro.kernels.move_eval import MOVE_BLOCKED, move_delta_reference

    rng = np.random.default_rng(7)
    n, m = 6, name_universe(6)
    ws = jnp.asarray(rng.uniform(0, 1.2, n), jnp.float32)
    assign = jnp.asarray(rng.integers(0, m, n), jnp.int32)
    prev = jnp.asarray(rng.integers(-1, m, n), jnp.int32)
    onehot = jax.nn.one_hot(assign, m)
    counts = onehot.sum(0).astype(jnp.int32)
    loads = (onehot * ws[:, None]).sum(0)
    lam = 1.7
    delta = np.asarray(move_delta_reference(loads, counts, assign, ws, prev,
                                            jnp.float32(lam),
                                            jnp.float32(C)))
    c0, _, _ = assignment_cost(assign, ws, prev, C, lam, m=m)
    for p in range(n):
        for b in range(m):
            c1, _, _ = assignment_cost(assign.at[p].set(b), ws, prev, C,
                                       lam, m=m)
            d_true = float(c1 - c0)
            if delta[p, b] < MOVE_BLOCKED / 2:
                assert delta[p, b] == pytest.approx(d_true, abs=1e-4), (p, b)
            else:
                w = float(ws[p])
                infeasible = not (float(loads[b]) + w <= C
                                  or (int(counts[b]) == 0 and w > C))
                assert b == int(assign[p]) or infeasible, (p, b)


# ---------------------------------------------------------------------------
# Pareto front / hypervolume
# ---------------------------------------------------------------------------
def test_pareto_front_basics():
    pts = [(3, 0.5), (4, 0.1), (3, 0.2), (5, 0.0), (4, 0.2), (3, 0.2)]
    assert pareto_front(pts) == [(3.0, 0.2), (4.0, 0.1), (5.0, 0.0)]
    assert dominated((4, 0.2), pareto_front(pts))
    assert not dominated((3, 0.2), pareto_front(pts))


def test_hypervolume_2d_values():
    ref = (4.0, 1.0)
    assert hypervolume_2d([(2.0, 0.5)], ref) == pytest.approx(1.0)
    # two-point staircase: (4-2)*(1-0.5) + (4-3)*(0.5-0.1)
    assert hypervolume_2d([(2.0, 0.5), (3.0, 0.1)], ref) == pytest.approx(1.4)
    # dominated and out-of-box points contribute nothing
    assert hypervolume_2d([(2.0, 0.5), (3.0, 0.6), (9.0, 0.0)], ref) == \
        pytest.approx(1.0)
    assert hypervolume_2d([], ref) == 0.0


def test_optimality_gap_shape_and_sign():
    g = optimality_gap([[3, 4], [2, 2]], [[3, 3], [2, 2]])
    np.testing.assert_allclose(g, [[0.0, 1 / 3], [0.0, 0.0]])


def test_anneal_frontier_contains_oracle_floor():
    """The frontier's minimum bin count equals the exact optimum, and the
    front is non-dominated and consistent with its per-lambda winners."""
    rng = np.random.default_rng(8)
    n = 8
    ws = rng.uniform(0, 0.6, n)
    prev = rng.integers(0, 5, n)
    fr = anneal_frontier(ws, prev, C, jax.random.key(2), restarts=3,
                         steps=300)
    opt = branch_and_bound(ws.tolist(), C).n_bins
    assert min(b for b, _ in fr.front) == opt
    assert fr.hypervolume > 0
    for p in fr.front:
        assert not dominated(p, fr.front)
    # per-lambda winners come from the same chain pool the front was drawn
    # from, so none may strictly dominate a frontier point
    for p in fr.per_lambda:
        assert not any(p[0] <= x and p[1] <= y and (p[0] < x or p[1] < y)
                       for x, y in fr.front)


# ---------------------------------------------------------------------------
# closed-loop policies
# ---------------------------------------------------------------------------
def test_policy_catalogue_includes_optimizers():
    from repro.lagsim import OPTIMIZER_POLICY_NAMES
    from repro.registry import list_policies

    assert set(OPTIMIZER_POLICY_NAMES) == {"ANNEAL", "ANNEAL_STICKY"}
    assert set(OPTIMIZER_POLICY_NAMES) < set(list_policies(backend="jax"))


def test_anneal_sticky_policy_drains_in_closed_loop():
    from repro.lagsim import LagSimConfig, simulate_lag

    cfg = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2)
    trace = jnp.tile(jnp.asarray([0.3, 0.4, 0.2], jnp.float32), (25, 1))
    r = simulate_lag(trace, policy="ANNEAL_STICKY", cfg=cfg)
    assert float(r.lag_total[-1]) == 0.0
    cons = np.asarray(r.consumers)
    assert (cons >= 1).all() and (cons <= 3).all()
    # once settled, a stability-priced optimizer stops migrating
    assert int(np.asarray(r.migrations)[10:].sum()) == 0


def test_anneal_policy_trades_stability_for_bins():
    """lambda = 0 (ANNEAL) churns more than ANNEAL_STICKY on the same
    stream -- the R-score term is what buys stability."""
    from repro.lagsim import LagSimConfig, sweep_lag

    cfg = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2)
    trace = jax.random.uniform(jax.random.key(3), (1, 20, 5), maxval=0.5)
    res = sweep_lag(("ANNEAL", "ANNEAL_STICKY"), trace, cfg)
    migs = np.asarray(res.migrations).sum(axis=(1, 2))
    assert migs[0] > migs[1]
