"""Regression and contract tests for the Sec. IV-C sticky naming rule and
the R-score missing-speed contract (no optional deps; the exhaustive
hypothesis properties live in ``test_sticky_property.py``).
"""
import pytest

from repro.core.binpack import pack
from repro.core.rscore import rscore, rscore_of_set

C = 1.0


def test_sticky_can_beat_fresh_naming_strictly():
    """Sanity check that the fresh-naming bound (see
    test_sticky_property.py) is not vacuous: when the packing is stable,
    sticky recovers R = 0 while fresh naming pays for every partition."""
    sp = {0: 0.4, 1: 0.5}
    prev = {0: 3, 1: 3}
    res = pack(sp, C, strategy="first", prev=prev, sticky=True)
    assert rscore(prev, res.pid_to_bin, sp, C) == 0.0
    assert rscore_of_set(set(prev), sp, C) == pytest.approx(0.9)


def test_sticky_not_always_below_nonsticky_sequential_naming():
    """Pinned counterexample: sticky CAN yield a higher R-score than
    sticky=False.  Non-sticky names the first bin 0, which happens to be
    partition B's previous consumer, so only A (speed 0.5) counts as
    moved; sticky deliberately reuses A's previous name 5 for the bin
    both items land in, so B (speed 1.0) counts as moved instead.  The
    adaptation optimizes for the *creating* item's continuity, not the
    bin's eventual contents -- hence the property suite asserts the
    fresh-naming bound, not a pointwise sticky <= non-sticky claim."""
    cap = 2.0
    sp = {0: 0.5, 1: 1.0}            # A, B
    prev = {0: 5, 1: 0}
    res_s = pack(sp, cap, strategy="first", prev=prev, sticky=True)
    res_n = pack(sp, cap, strategy="first", prev=prev, sticky=False)
    assert res_s.n_bins == res_n.n_bins == 1
    r_s = rscore(prev, res_s.pid_to_bin, sp, cap)
    r_n = rscore(prev, res_n.pid_to_bin, sp, cap)
    assert r_s == pytest.approx(0.5)
    assert r_n == pytest.approx(0.25)
    assert r_s > r_n


# ---------------------------------------------------------------------------
# R-score missing-speed contract
# ---------------------------------------------------------------------------
def test_rscore_missing_default_counts_zero():
    """Documented contract: a moved partition without a speed sample (the
    monitor has not measured it yet) contributes 0 by default."""
    assert rscore_of_set({"p0", "ghost"}, {"p0": 0.5}, 1.0) == 0.5


def test_rscore_missing_raise_names_partitions():
    with pytest.raises(KeyError, match="ghost"):
        rscore_of_set({"p0", "ghost"}, {"p0": 0.5}, 1.0, missing="raise")
    # total speed maps pass strict mode untouched
    assert rscore_of_set({"p0"}, {"p0": 0.5}, 1.0, missing="raise") == 0.5


def test_rscore_missing_kwarg_validated_and_threaded():
    with pytest.raises(ValueError, match="missing"):
        rscore_of_set(set(), {}, 1.0, missing="ignore")
    with pytest.raises(KeyError, match="ghost"):
        rscore({"ghost": 0, "p0": 0}, {"ghost": 1, "p0": 0}, {"p0": 0.5},
               1.0, missing="raise")
