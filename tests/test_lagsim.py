"""Closed-loop lag simulator tests.

Load-bearing properties (ISSUE acceptance criteria):

* the fused Pallas lag-update kernel is bit-equal to its jnp oracle, and
  the engine produces identical trajectories through either path;
* batch-size-1 sweeps are bit-identical to the single-stream path;
* the twin reproduces ``serving/simulation.py`` lag trajectories on a
  constant-rate golden scenario within a few record quanta.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.lag_update import lag_update_batch, lag_update_reference
from repro.lagsim import (
    REACTIVE_BASELINE_NAMES,
    LagSimConfig,
    longest_excursion,
    simulate_lag,
    slo_summary,
    summarize_sweep,
    sweep_lag,
)
from repro.registry import list_policies

CFG = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2)


def _constant(T, rates):
    return jnp.tile(jnp.asarray(rates, jnp.float32), (T, 1))


# ---------------------------------------------------------------------------
# engine basics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ("BFD", "KEDA_LAG"))
def test_simulate_shapes_and_dtypes(policy):
    r = simulate_lag(_constant(12, [0.3, 0.4, 0.2]), policy=policy, cfg=CFG)
    for arr, dt in ((r.lag_total, jnp.float32), (r.lag_max, jnp.float32),
                    (r.consumers, jnp.int32), (r.migrations, jnp.int32),
                    (r.unreadable, jnp.int32)):
        assert arr.shape == (12,)
        assert arr.dtype == dt


@pytest.mark.parametrize("policy", ("FFD", "MBFP", "RATE_THRESHOLD"))
def test_underload_drains_to_zero(policy):
    """Constant rates well under capacity: backlog vanishes, no churn after
    the assignment settles."""
    r = simulate_lag(_constant(30, [0.3, 0.4, 0.2, 0.35]), policy=policy,
                     cfg=CFG)
    assert float(r.lag_total[-1]) == 0.0
    assert int(np.asarray(r.migrations)[5:].sum()) == 0


def test_overload_grows_at_excess_rate():
    """A partition above capacity backlogs at exactly (rate - C) * dt."""
    r = simulate_lag(_constant(40, [1.5, 0.2, 0.2]), policy="BFD", cfg=CFG)
    lt = np.asarray(r.lag_total)
    np.testing.assert_allclose(np.diff(lt[-10:]), 0.5, rtol=1e-5)


def test_initial_lag_seeds_backlog():
    trace = _constant(20, [0.1, 0.1])
    r0 = simulate_lag(trace, policy="BFD", cfg=CFG)
    r1 = simulate_lag(trace, policy="BFD", cfg=CFG,
                      initial_lag=jnp.asarray([5.0, 0.0], jnp.float32))
    assert float(r1.lag_total[0]) > float(r0.lag_total[0])
    # one consumer drains the seeded spike at capacity
    lt = np.asarray(r1.lag_total)
    assert float(lt[-1]) == 0.0
    np.testing.assert_allclose(np.diff(lt[:4]), -0.8, rtol=1e-5)


def test_migration_downtime_costs_lag():
    """The same thrashy policy with longer downtime windows must backlog
    strictly more: unreadable partitions keep producing."""
    spike = jnp.where(jnp.arange(40)[:, None] < 20, 0.2, 0.9)
    trace = jnp.tile(spike, (1, 5)).astype(jnp.float32)
    peaks = []
    for steps in (0, 4):
        cfg = dataclasses.replace(CFG, migration_steps=steps)
        r = simulate_lag(trace, policy="KEDA_LAG", cfg=cfg)
        peaks.append(float(np.asarray(r.lag_total).max()))
        if steps:
            assert int(np.asarray(r.unreadable).sum()) > 0
    assert peaks[1] > peaks[0]


def test_reactive_baseline_scales_with_load():
    """KEDA-style scaler adds consumers when backlog crosses the threshold
    and releases them (after the patience window) once it drains."""
    trace = jnp.concatenate([
        _constant(10, [0.1] * 6), _constant(10, [0.8] * 6),
        _constant(25, [0.1] * 6)])
    r = simulate_lag(trace, policy="KEDA_LAG", cfg=CFG)
    n = np.asarray(r.consumers)
    assert n[:5].max() == 1
    assert n[10:20].max() >= 3
    assert n[-1] <= 2
    assert int(np.asarray(r.migrations).sum()) > 0


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown policy"):
        simulate_lag(_constant(4, [0.1]), policy="PID", cfg=CFG)


def test_partition_count_mismatch_raises_clear_error():
    """Satellite fix: a wrong-length initial_lag used to blow up as an
    opaque broadcast error deep inside the scan; now it is a ValueError
    naming both shapes up front."""
    trace = _constant(6, [0.3, 0.4, 0.2])          # n = 3
    with pytest.raises(ValueError, match=r"initial_lag has shape \(2,\)"):
        simulate_lag(trace, policy="BFD", cfg=CFG,
                     initial_lag=jnp.zeros(2, jnp.float32))
    with pytest.raises(ValueError, match="rates.shape\\[-1\\]"):
        simulate_lag(trace, policy="BFD", cfg=CFG,
                     initial_lag=jnp.zeros(5, jnp.float32))
    with pytest.raises(ValueError, match="active mask has shape"):
        simulate_lag(trace, policy="BFD", cfg=CFG,
                     active=jnp.ones((6, 4), bool))
    with pytest.raises(ValueError, match=r"must be f32\[T, N\]"):
        simulate_lag(jnp.zeros((4, 3, 2)), policy="BFD", cfg=CFG)
    with pytest.raises(ValueError, match=r"must be f32\[B, T, N\]"):
        sweep_lag(("BFD",), jnp.zeros((4, 3)), CFG)
    with pytest.raises(ValueError, match="active mask has shape"):
        sweep_lag(("BFD",), jnp.zeros((1, 4, 3)), CFG,
                  active=jnp.ones((1, 4, 2), bool))


# ---------------------------------------------------------------------------
# masked partitions: unreadable and empty
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ("BFD", "KEDA_LAG"))
def test_all_active_mask_reproduces_unmasked_trajectories(policy):
    trace = jax.random.uniform(jax.random.key(3), (16, 5), maxval=0.8)
    a = simulate_lag(trace, policy=policy, cfg=CFG)
    b = simulate_lag(trace, policy=policy, cfg=CFG,
                     active=jnp.ones((16, 5), bool))
    np.testing.assert_array_equal(np.asarray(a.lag_total),
                                  np.asarray(b.lag_total))
    np.testing.assert_array_equal(np.asarray(a.consumers),
                                  np.asarray(b.consumers))
    np.testing.assert_array_equal(np.asarray(a.migrations),
                                  np.asarray(b.migrations))


def test_masked_partition_is_unreadable_and_empty():
    """A partition that dies keeps zero recorded lag while dead -- it
    produces nothing and its stale backlog is dropped with the topic --
    and the consumer count shrinks to the live load."""
    rates = jnp.full((12, 2), 0.9, jnp.float32)
    active = jnp.stack([jnp.ones(12, bool),
                        jnp.arange(12) < 6], axis=1)   # p1 dies at t=6
    r = simulate_lag(rates, policy="BFD", cfg=CFG, active=active)
    lt = np.asarray(r.lag_total)
    cons = np.asarray(r.consumers)
    assert (cons[:6] == 2).all() and (cons[6:] == 1).all()
    # both partitions fit capacity exactly => no backlog while both live,
    # and p1's disappearance leaves p0's zero backlog untouched
    assert (lt == 0.0).all()
    r2 = simulate_lag(rates, policy="BFD", cfg=CFG)
    assert (np.asarray(r2.consumers) == 2).all()


def test_policy_name_catalogue():
    policy_names = list_policies(backend="jax")
    assert set(REACTIVE_BASELINE_NAMES) == {
        "KEDA_LAG", "RATE_THRESHOLD", "KEDA_LAG_REAL", "CLOUD_RUN_CPU_LAG"}
    assert set(REACTIVE_BASELINE_NAMES) < set(policy_names)
    assert "MBFP" in policy_names


# ---------------------------------------------------------------------------
# sweep driver
# ---------------------------------------------------------------------------
def test_sweep_batch1_bit_identical_to_single_stream():
    trace = jax.random.uniform(jax.random.key(0), (24, 6), maxval=0.8)
    res = sweep_lag(("BFD", "KEDA_LAG"), trace[None], CFG)
    for p in ("BFD", "KEDA_LAG"):
        solo = simulate_lag(trace, policy=p, cfg=CFG)
        got = res.for_policy(p)
        np.testing.assert_array_equal(np.asarray(got.lag_total[0]),
                                      np.asarray(solo.lag_total))
        np.testing.assert_array_equal(np.asarray(got.consumers[0]),
                                      np.asarray(solo.consumers))
        np.testing.assert_array_equal(np.asarray(got.migrations[0]),
                                      np.asarray(solo.migrations))


def test_sweep_rows_match_individual_streams():
    traces = jax.random.uniform(jax.random.key(1), (3, 16, 5), maxval=0.7)
    res = sweep_lag(("FFD",), traces, CFG)
    for b in range(3):
        solo = sweep_lag(("FFD",), traces[b:b + 1], CFG)
        np.testing.assert_array_equal(np.asarray(res.lag_total[:, b]),
                                      np.asarray(solo.lag_total[:, 0]))


# ---------------------------------------------------------------------------
# fused Pallas kernel vs jnp oracle
# ---------------------------------------------------------------------------
def test_lag_update_kernel_matches_reference():
    rng = np.random.default_rng(0)
    for b, n, mm in ((1, 4, 10), (3, 12, 26), (2, 33, 68)):
        lag = jnp.asarray(rng.uniform(0, 5, (b, n)), jnp.float32)
        prod = jnp.asarray(rng.uniform(0, 1, (b, n)), jnp.float32)
        assign = jnp.asarray(rng.integers(-1, mm, (b, n)), jnp.int32)
        readable = jnp.asarray(rng.integers(0, 2, (b, n)), jnp.int32)
        cap = jnp.full((b, mm), 1.3, jnp.float32)
        out_k = lag_update_batch(lag, prod, assign, readable, cap)
        out_r = lag_update_reference(lag, prod, assign, readable, cap, m=mm)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-6, atol=1e-6)


def test_lag_update_budget_conservation():
    """Per consumer, total bytes drained in one step never exceed cap."""
    rng = np.random.default_rng(7)
    b, n, mm = 2, 20, 14
    lag = jnp.asarray(rng.uniform(0, 3, (b, n)), jnp.float32)
    prod = jnp.asarray(rng.uniform(0, 1, (b, n)), jnp.float32)
    assign = jnp.asarray(rng.integers(0, mm, (b, n)), jnp.int32)
    readable = jnp.ones((b, n), jnp.int32)
    cap = jnp.full((b, mm), 0.9, jnp.float32)
    out = np.asarray(lag_update_batch(lag, prod, assign, readable, cap))
    drained = np.asarray(lag + prod) - out
    assert (drained >= -1e-6).all()
    for bi in range(b):
        for c in range(mm):
            sel = np.asarray(assign)[bi] == c
            assert drained[bi][sel].sum() <= 0.9 + 1e-5


def test_engine_kernel_path_matches_jnp_path():
    trace = jax.random.uniform(jax.random.key(5), (18, 7), maxval=0.6)
    a = simulate_lag(trace, policy="MBFP", cfg=CFG)
    b = simulate_lag(trace, policy="MBFP",
                     cfg=dataclasses.replace(CFG, use_kernel=True))
    np.testing.assert_allclose(np.asarray(a.lag_total),
                               np.asarray(b.lag_total), rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.consumers),
                                  np.asarray(b.consumers))


# ---------------------------------------------------------------------------
# SLO metrics
# ---------------------------------------------------------------------------
def test_longest_excursion():
    mask = np.array([[0, 1, 1, 1, 0, 1, 0], [1, 1, 0, 0, 1, 1, 1]], bool)
    np.testing.assert_array_equal(longest_excursion(mask), [3, 3])


def test_slo_summary_values():
    lag = np.array([0.0, 3.0, 3.0, 0.5, 0.0])
    cons = np.array([1, 2, 2, 2, 1])
    migs = np.array([0, 3, 0, 0, 2])
    s = slo_summary(lag, cons, migs, slo_lag=1.0, dt=2.0)
    assert s["peak_lag"] == 3.0
    assert s["violation_frac"] == pytest.approx(0.4)
    assert s["time_to_drain"] == 4.0          # 2 steps x dt
    assert s["consumer_seconds"] == 16.0
    assert s["total_migrations"] == 5


def test_summarize_sweep_shapes():
    traces = jax.random.uniform(jax.random.key(2), (2, 10, 4), maxval=0.9)
    res = sweep_lag(("BFD", "RATE_THRESHOLD"), traces, CFG)
    s = summarize_sweep(res, CFG)
    for v in s.values():
        assert v.shape == (2, 2)


# ---------------------------------------------------------------------------
# golden cross-validation against the Python closed loop
# ---------------------------------------------------------------------------
def test_golden_matches_python_simulation():
    """``repro.lagsim`` reproduces ``serving/simulation.py`` lag
    trajectories on a constant-rate scenario.

    The Python world is synchronized out of its startup transient (consumer
    creation + two-phase handoff have no fixed-step equivalent), then both
    simulators run the same constant workload from the same per-partition
    backlog.  ``batch_bytes`` is clamped to ``capacity * dt`` so the Python
    replica is the paper's constant-rate-C consumer (its default config
    banks unused budget and bursts above C at up to ``batch_bytes``/s,
    which the twin deliberately does not model).  Agreement is within a
    few record quanta per step.
    """
    from repro.broker import TopicPartition
    from repro.serving import AutoscaleSimulation

    cap = 1.0e6
    rates = [0.3e6, 0.5e6, 0.4e6, 0.6e6, 0.2e6, 0.45e6]
    n = len(rates)
    t_sync, t_run = 8, 60
    record_bytes = 64
    sim = AutoscaleSimulation(
        n_partitions=n, rate_fn=AutoscaleSimulation.constant_rates(rates),
        capacity=cap, algorithm="BFD", record_bytes=record_bytes,
        monitor_interval=1.0)
    sim.replica_cfg.batch_bytes = int(cap)
    sim.manager.config.batch_bytes = int(cap)
    sim.run(seconds=t_sync, dt=1.0)
    lag0 = np.array([sim.broker.lag("autoscaler", TopicPartition("sensors", i))
                     for i in range(n)], np.float32)
    m = sim.run(seconds=t_run, dt=1.0)
    py_lag = np.asarray(m.lag_bytes, float)[t_sync:]
    py_n = np.asarray(m.n_replicas)[t_sync:]

    trace = jnp.tile(jnp.asarray(rates, jnp.float32), (t_run, 1))
    r = simulate_lag(trace, policy="BFD",
                     cfg=LagSimConfig(capacity=cap, dt=1.0),
                     initial_lag=jnp.asarray(lag0))
    jx_lag = np.asarray(r.lag_total)
    # consumer counts agree exactly; lag within a few record quanta
    np.testing.assert_array_equal(py_n, np.asarray(r.consumers))
    tol = 4 * record_bytes * n
    assert np.abs(py_lag - jx_lag).max() <= tol, (
        f"lag divergence {np.abs(py_lag - jx_lag).max():.0f} B > {tol} B")
    # and nothing migrated in either world under constant load
    assert int(np.asarray(r.migrations).sum()) == 0
