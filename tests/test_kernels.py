"""Pallas kernel validation: interpret-mode execution vs the pure-jnp
oracles in kernels/ref.py, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.binpack_select import select_slot_batch, select_slot_grid
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.move_eval import (
    MOVE_BLOCKED,
    move_delta_batch,
    move_delta_reference,
)
from repro.kernels.rwkv6_scan import rwkv6_wkv_fwd


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kv,sq,skv,hd", [
    (1, 4, 4, 128, 128, 64),       # MHA square
    (2, 8, 2, 128, 256, 64),       # GQA, rectangular
    (1, 4, 1, 256, 256, 128),      # MQA, bigger head
    (1, 2, 2, 64, 192, 32),        # uneven kv blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, h, kv, sq, skv, hd, dtype, causal):
    ks = jax.random.split(jax.random.key(0), 3)
    q = _rand(ks[0], (b, h, sq, hd), dtype)
    k = _rand(ks[1], (b, kv, skv, hd), dtype)
    v = _rand(ks[2], (b, kv, skv, hd), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_block_shape_sweep():
    ks = jax.random.split(jax.random.key(1), 3)
    q = _rand(ks[0], (1, 2, 256, 64), jnp.float32)
    k = _rand(ks[1], (1, 2, 256, 64), jnp.float32)
    v = _rand(ks[2], (1, 2, 256, 64), jnp.float32)
    want = ref.attention_ref(q, k, v, causal=True)
    for bq, bk in [(32, 64), (64, 32), (128, 128), (256, 64)]:
        out = flash_attention_fwd(q, k, v, causal=True, block_q=bq,
                                  block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5,
                                   err_msg=f"block {bq}x{bk}")


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,kv,g,s,hd", [
    (2, 2, 4, 256, 64),    # GQA
    (1, 4, 1, 128, 128),   # MHA
    (3, 1, 8, 512, 64),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("fill", [0, 7, 200])
def test_decode_attention_matches_ref(b, kv, g, s, hd, dtype, fill):
    if fill >= s:
        pytest.skip("fill beyond cache")
    ks = jax.random.split(jax.random.key(2), 3)
    q = _rand(ks[0], (b, kv, g, hd), dtype)
    k_cache = _rand(ks[1], (b, kv, s, hd), dtype)
    v_cache = _rand(ks[2], (b, kv, s, hd), dtype)
    out = decode_attention_fwd(q, k_cache, v_cache, jnp.int32(fill),
                               block_s=64, interpret=True)
    want = ref.decode_attention_ref(q, k_cache, v_cache, jnp.int32(fill))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,t,h,hd", [(1, 16, 2, 16), (2, 64, 4, 32),
                                      (1, 128, 1, 64)])
def test_rwkv6_wkv_matches_ref(b, t, h, hd):
    ks = jax.random.split(jax.random.key(3), 6)
    r = _rand(ks[0], (b, t, h, hd), jnp.float32)
    k = _rand(ks[1], (b, t, h, hd), jnp.float32) * 0.3
    v = _rand(ks[2], (b, t, h, hd), jnp.float32)
    w = jax.nn.sigmoid(_rand(ks[3], (b, t, h, hd), jnp.float32)) * 0.5 + 0.45
    u = _rand(ks[4], (h, hd), jnp.float32) * 0.1
    s0 = _rand(ks[5], (b, h, hd, hd), jnp.float32) * 0.1
    out, s_last = rwkv6_wkv_fwd(r, k, v, w, u, s0, interpret=True)
    want, s_want = ref.rwkv6_wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_last), np.asarray(s_want),
                               atol=1e-4, rtol=1e-4)


def test_rwkv6_wkv_chunked_wrapper():
    from repro.kernels.ops import rwkv6_wkv
    b, t, h, hd = 1, 64, 2, 16
    ks = jax.random.split(jax.random.key(4), 6)
    r = _rand(ks[0], (b, t, h, hd), jnp.float32)
    k = _rand(ks[1], (b, t, h, hd), jnp.float32) * 0.3
    v = _rand(ks[2], (b, t, h, hd), jnp.float32)
    w = jnp.full((b, t, h, hd), 0.9, jnp.float32)
    u = _rand(ks[4], (h, hd), jnp.float32) * 0.1
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    out_c, s_c = rwkv6_wkv(r, k, v, w, u, s0, chunk=16)
    want, s_want = ref.rwkv6_wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_want),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# binpack fit selection
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ["first", "best", "worst"])
def test_select_slot_matches_ref_and_packer(strategy):
    rng = np.random.default_rng(0)
    n, m = 64, 32
    loads = rng.uniform(0, 1, (n, m)).astype(np.float32)
    w = rng.uniform(0, 0.6, (n,)).astype(np.float32)
    k = rng.integers(0, m + 1, (n,)).astype(np.int32)
    cap = np.ones((n,), np.float32)
    got = select_slot_batch(jnp.asarray(loads), jnp.asarray(w),
                            jnp.asarray(k), jnp.asarray(cap),
                            strategy=strategy, interpret=True)
    want = ref.select_slot_ref(jnp.asarray(loads), jnp.asarray(w),
                               jnp.asarray(k), jnp.asarray(cap),
                               strategy=strategy)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # cross-check against the scalar packer used by the controller
    from repro.core.jaxpack import _select_slot
    for i in range(8):
        slot, found = _select_slot(jnp.asarray(loads[i]), jnp.asarray(k[i]),
                                   jnp.asarray(w[i]), jnp.asarray(cap[i]),
                                   strategy)
        exp = int(want[i])
        if exp == m:
            assert not bool(found)
        else:
            assert bool(found) and int(slot) == exp


# ---------------------------------------------------------------------------
# annealer move evaluation
# ---------------------------------------------------------------------------
def _random_chain_state(rng, k, n, m):
    """A consistent (loads, counts, assign) batch derived from assignments,
    as the annealer maintains it."""
    speeds = rng.uniform(0, 1.2, (k, n)).astype(np.float32)
    assign = rng.integers(0, m, (k, n)).astype(np.int32)
    onehot = np.eye(m, dtype=np.float32)[assign]            # (K, N, M)
    counts = onehot.sum(axis=1).astype(np.int32)
    loads = (onehot * speeds[..., None]).sum(axis=1).astype(np.float32)
    return speeds, assign, loads, counts


@pytest.mark.parametrize("k,n,m", [(1, 4, 10), (7, 6, 14), (3, 24, 50)])
def test_move_eval_kernel_matches_ref(k, n, m):
    rng = np.random.default_rng(11)
    speeds, assign, loads, counts = _random_chain_state(rng, k, n, m)
    prev = rng.integers(-1, m, (k, n)).astype(np.int32)
    lam = np.linspace(0.0, 8.0, k).astype(np.float32)
    cap = np.full(k, 1.0, np.float32)
    got = move_delta_batch(jnp.asarray(loads), jnp.asarray(counts),
                           jnp.asarray(assign), jnp.asarray(speeds),
                           jnp.asarray(prev), jnp.asarray(lam),
                           jnp.asarray(cap), interpret=True)
    want = move_delta_reference(jnp.asarray(loads), jnp.asarray(counts),
                                jnp.asarray(assign), jnp.asarray(speeds),
                                jnp.asarray(prev), jnp.asarray(lam),
                                jnp.asarray(cap))
    assert got.shape == (k, n, m)
    # identical mask, near-identical values (one fused multiply of float32s)
    np.testing.assert_array_equal(np.asarray(got) >= MOVE_BLOCKED / 2,
                                  np.asarray(want) >= MOVE_BLOCKED / 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_move_eval_masks_current_bin_and_capacity():
    """No-op moves and capacity violations are MOVE_BLOCKED; an oversized
    item may still enter an empty bin (its dedicated overflow bin)."""
    loads = jnp.asarray([[0.9, 0.0, 0.5]], jnp.float32)
    counts = jnp.asarray([[1, 0, 1]], jnp.int32)
    assign = jnp.asarray([[0, 2]], jnp.int32)
    speeds = jnp.asarray([[0.9, 0.5]], jnp.float32)
    prev = jnp.asarray([[-1, -1]], jnp.int32)
    one = jnp.ones(1, jnp.float32)
    d = np.asarray(move_delta_batch(loads, counts, assign, speeds, prev,
                                    0 * one, one, interpret=True))[0]
    assert d[0, 0] >= MOVE_BLOCKED / 2          # own bin: no-op
    assert d[0, 2] >= MOVE_BLOCKED / 2          # 0.5 + 0.9 > C
    assert d[0, 1] == pytest.approx(0.0)        # empty bin: open one, close one
    assert d[1, 0] >= MOVE_BLOCKED / 2          # 0.9 + 0.5 > C
    assert d[1, 1] == pytest.approx(0.0)
    # oversized item alone may take an empty bin
    speeds2 = jnp.asarray([[1.4, 0.5]], jnp.float32)
    loads2 = jnp.asarray([[1.4, 0.0, 0.5]], jnp.float32)
    d2 = np.asarray(move_delta_batch(loads2, counts, assign, speeds2, prev,
                                     0 * one, one, interpret=True))[0]
    assert d2[0, 1] == pytest.approx(0.0)       # overflow bin relocation
    assert d2[0, 2] >= MOVE_BLOCKED / 2         # may not join an occupied bin


@pytest.mark.parametrize("strategy", ["first", "best", "worst"])
@pytest.mark.parametrize("b,n,m,tile", [
    (1, 64, 32, 64),     # singleton batch, exact tile
    (4, 50, 16, 16),     # batch, padded rows (50 % 16 != 0)
    (3, 300, 8, 128),    # multi-tile rows
])
def test_select_slot_grid_matches_ref(strategy, b, n, m, tile):
    """Batched-grid kernel == per-stream oracle, including padded tiles."""
    rng = np.random.default_rng(1)
    loads = rng.uniform(0, 1, (b, n, m)).astype(np.float32)
    w = rng.uniform(0, 0.6, (b, n)).astype(np.float32)
    k = rng.integers(0, m + 1, (b, n)).astype(np.int32)
    cap = np.ones((b, n), np.float32)
    got = select_slot_grid(jnp.asarray(loads), jnp.asarray(w),
                           jnp.asarray(k), jnp.asarray(cap),
                           strategy=strategy, row_tile=tile, interpret=True)
    want = np.stack([
        np.asarray(ref.select_slot_ref(jnp.asarray(loads[i]),
                                       jnp.asarray(w[i]), jnp.asarray(k[i]),
                                       jnp.asarray(cap[i]),
                                       strategy=strategy))
        for i in range(b)
    ])
    np.testing.assert_array_equal(np.asarray(got), want)
