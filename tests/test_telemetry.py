"""Flight-recorder telemetry tests.

Load-bearing properties (ISSUE acceptance criteria):

* telemetry **off** (``telemetry=None`` or ``enabled=False``) is
  bit-identical to the pre-telemetry engine, on the direct path AND
  through the fleet's padded buckets (hypothesis property with a
  deterministic fixed-seed fallback);
* telemetry **on** never changes the simulated trajectories -- the
  recorder only reads values the step already computes;
* the fleet's padded-bucket frames match the direct engine's frames on
  the true steps; ring mode through the fleet raises a named error;
* a fixed-seed ``topic_lifecycle`` run decodes to the checked-in golden
  event stream (``tests/data/golden_telemetry_events.json``);
* the host-side tracer produces valid Chrome/Perfetto traces, separates
  first-call from steady-state, and stays bounded;
* the bench regression gate (``benchmarks/bench_diff.py``) passes an
  identity diff and catches an injected 50% throughput regression.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp

from repro.fleet import FleetConfig, FleetRunner
from repro.core.scenarios import generate_masked_scenario
from repro.lagsim import LagSimConfig, simulate_lag, sweep_lag
from repro.telemetry import (
    BASE_CHANNELS,
    EventStream,
    TelemetryConfig,
    Tracer,
    decode_events,
    span,
    traced,
    validate_chrome_trace,
)

DATA = os.path.join(os.path.dirname(__file__), "data")
GOLDEN = os.path.join(DATA, "golden_telemetry_events.json")

CFG = LagSimConfig(capacity=1.0, dt=1.0, migration_steps=2)
TRACE_FIELDS = ("lag_total", "lag_max", "consumers", "migrations",
                "unreadable")
POLICIES = ("MBFP", "KEDA_LAG")


def _with_tele(cfg, **kw):
    return dataclasses.replace(cfg, telemetry=TelemetryConfig(**kw))


def _scenario(seed=0, batch=2, t=24, n=6):
    """A fixed topic_lifecycle batch: births/deaths, storms, migrations."""
    return generate_masked_scenario(
        "topic_lifecycle", jax.random.key(seed), batch, t, n)


# ---------------------------------------------------------------------------
# off == bit-identical (the goldens' guarantee)
# ---------------------------------------------------------------------------

def _assert_bit_identical(a, b):
    for f in TRACE_FIELDS:
        assert np.asarray(getattr(a, f)).tobytes() == \
            np.asarray(getattr(b, f)).tobytes(), f


@pytest.mark.parametrize("policy", POLICIES)
def test_off_is_bit_identical_direct(policy):
    """telemetry=None and TelemetryConfig(enabled=False) produce the
    exact bytes of each other -- the disabled config compiles to the
    pre-telemetry jaxpr."""
    speeds, active = _scenario()
    off = simulate_lag(speeds[0], policy=policy, cfg=CFG, active=active[0])
    dis = simulate_lag(speeds[0], policy=policy,
                       cfg=_with_tele(CFG, enabled=False), active=active[0])
    _assert_bit_identical(off, dis)
    assert off.telemetry is None
    assert dis.telemetry is None


@pytest.mark.parametrize("policy", POLICIES)
def test_on_trajectories_unchanged_direct(policy):
    """The recorder only reads values the step computes: trajectories
    with telemetry on are bit-identical to off."""
    speeds, active = _scenario()
    off = simulate_lag(speeds[0], policy=policy, cfg=CFG, active=active[0])
    on = simulate_lag(speeds[0], policy=policy, cfg=_with_tele(CFG),
                      active=active[0])
    _assert_bit_identical(off, on)
    frame = on.telemetry
    assert frame is not None
    t, k = speeds.shape[1], len(frame.names)
    assert frame.names[:len(BASE_CHANNELS)] == BASE_CHANNELS
    assert frame.channels.shape == (t, k)
    assert int(frame.count) == t
    assert np.array_equal(np.asarray(frame.steps), np.arange(t))


def test_off_is_bit_identical_fleet_padded():
    """Same property through the fleet's padded buckets (T and N both
    rounded up)."""
    speeds, active = _scenario(t=20, n=5)
    fleet = FleetRunner(FleetConfig(t_buckets=(32,), n_buckets=(8,)))
    off = fleet.simulate(POLICIES, speeds, CFG, active=active)
    dis = fleet.simulate(POLICIES, speeds, _with_tele(CFG, enabled=False),
                         active=active)
    for i in range(speeds.shape[0]):
        for f in TRACE_FIELDS:
            assert np.asarray(getattr(off, f)[i]).tobytes() == \
                np.asarray(getattr(dis, f)[i]).tobytes(), (i, f)
    assert off.telemetry is None
    assert dis.telemetry is None


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), t=st.integers(4, 24),
           n=st.integers(2, 8))
    def test_off_bit_identical_property(seed, t, n):
        speeds, active = _scenario(seed=seed, batch=1, t=t, n=n)
        off = simulate_lag(speeds[0], policy="MBFP", cfg=CFG,
                           active=active[0])
        dis = simulate_lag(speeds[0], policy="MBFP",
                           cfg=_with_tele(CFG, enabled=False),
                           active=active[0])
        on = simulate_lag(speeds[0], policy="MBFP", cfg=_with_tele(CFG),
                          active=active[0])
        _assert_bit_identical(off, dis)
        _assert_bit_identical(off, on)


def test_off_bit_identical_fixed_seeds():
    """Deterministic fallback of the hypothesis property above (always
    runs, with or without hypothesis installed)."""
    for seed, t, n in ((0, 4, 2), (1, 13, 5), (7, 24, 8)):
        speeds, active = _scenario(seed=seed, batch=1, t=t, n=n)
        off = simulate_lag(speeds[0], policy="MBFP", cfg=CFG,
                           active=active[0])
        dis = simulate_lag(speeds[0], policy="MBFP",
                           cfg=_with_tele(CFG, enabled=False),
                           active=active[0])
        on = simulate_lag(speeds[0], policy="MBFP", cfg=_with_tele(CFG),
                          active=active[0])
        _assert_bit_identical(off, dis)
        _assert_bit_identical(off, on)


# ---------------------------------------------------------------------------
# recorder semantics: sweep stacking, fleet padding, ring mode
# ---------------------------------------------------------------------------

def test_sweep_stacks_frames_and_for_policy_slices():
    speeds, active = _scenario()
    res = sweep_lag(POLICIES, speeds, cfg=_with_tele(CFG), active=active)
    p, b, t = len(POLICIES), speeds.shape[0], speeds.shape[1]
    k = len(res.telemetry.names)
    assert res.telemetry.channels.shape == (p, b, t, k)
    for pi, pol in enumerate(POLICIES):
        one = res.for_policy(pol)
        direct = jax.vmap(
            lambda tr, act: simulate_lag(tr, policy=pol,
                                         cfg=_with_tele(CFG), active=act)
        )(speeds, active)
        assert np.array_equal(np.asarray(one.telemetry.channels),
                              np.asarray(direct.telemetry.channels))


def test_fleet_padded_frames_match_direct():
    """Bucket padding must not leak into the recorded frames: the fleet's
    per-scenario frame equals the direct engine's on the true steps."""
    speeds, active = _scenario(t=20, n=5)
    fleet = FleetRunner(FleetConfig(t_buckets=(32,), n_buckets=(8,)))
    res = fleet.simulate(POLICIES, speeds, _with_tele(CFG), active=active)
    assert res.telemetry is not None
    t = speeds.shape[1]
    for i in range(speeds.shape[0]):
        frame = res.telemetry[i]             # [P, t, K]
        assert frame.channels.shape[1] == t
        for pi, pol in enumerate(POLICIES):
            direct = simulate_lag(speeds[i], policy=pol,
                                  cfg=_with_tele(CFG), active=active[i])
            assert np.array_equal(np.asarray(frame.channels[pi]),
                                  np.asarray(direct.telemetry.channels)), \
                (i, pol)


def test_ring_mode_keeps_exact_tail():
    speeds, active = _scenario(batch=1, t=40, n=6)
    full = simulate_lag(speeds[0], policy="MBFP", cfg=_with_tele(CFG),
                        active=active[0])
    ring = simulate_lag(speeds[0], policy="MBFP",
                        cfg=_with_tele(CFG, ring=8), active=active[0])
    rf = ring.telemetry
    assert rf.channels.shape[0] == 8
    assert int(rf.count) == 40
    order = np.argsort(np.asarray(rf.steps), kind="stable")
    assert np.array_equal(np.asarray(rf.steps)[order], np.arange(32, 40))
    assert np.array_equal(np.asarray(rf.channels)[order],
                          np.asarray(full.telemetry.channels)[32:])


def test_ring_through_fleet_raises():
    """Padded bucket tails are not history: ring mode must be refused by
    the fleet before anything compiles."""
    speeds, active = _scenario(t=20, n=5)
    fleet = FleetRunner(FleetConfig(t_buckets=(32,), n_buckets=(8,)))
    with pytest.raises(ValueError, match="ring"):
        fleet.simulate(POLICIES, speeds, _with_tele(CFG, ring=8),
                       active=active)


def test_telemetry_config_validation():
    with pytest.raises(ValueError, match="lag_quantiles"):
        TelemetryConfig(lag_quantiles=(1.5,))
    with pytest.raises(ValueError, match="ring"):
        TelemetryConfig(ring=0)
    with pytest.raises(ValueError, match="telemetry"):
        LagSimConfig(capacity=1.0, telemetry="yes").resolve(4)


# ---------------------------------------------------------------------------
# event decoding: golden stream + internal consistency
# ---------------------------------------------------------------------------

def _golden_stream():
    """The exact fixed-seed run the golden file pins (see the generator
    note inside the golden)."""
    speeds, active = _scenario(seed=0, batch=2, t=32, n=8)
    res = simulate_lag(speeds[0], policy="MBFP", cfg=_with_tele(CFG),
                       active=active[0])
    return EventStream.from_frame(res.telemetry)


def test_golden_event_stream():
    with open(GOLDEN) as f:
        want = json.load(f)
    got = json.loads(_golden_stream().to_json())
    assert got["channels"] == want["channels"]
    assert got["recorded_steps"] == want["recorded_steps"]
    assert got["counts"] == want["counts"]
    assert len(got["events"]) == len(want["events"])
    for g, w in zip(got["events"], want["events"]):
        assert (g["kind"], g["step"], g["index"]) == \
            (w["kind"], w["step"], w["index"])
        assert set(g["data"]) == set(w["data"])
        for key in g["data"]:
            assert g["data"][key] == pytest.approx(w["data"][key],
                                                   abs=1e-5), (g, w, key)


def test_event_stream_consistency():
    stream = _golden_stream()
    events = stream.events
    assert events, "the lifecycle scenario must produce events"
    counts = stream.counts()
    assert sum(counts.values()) == len(events)
    assert {"scale", "migration", "lifecycle"} <= set(counts)
    # every event's step must be a recorded step
    steps = set(np.asarray(stream.frame.steps).ravel().tolist())
    for e in events:
        assert e.step in steps
    # decode_events is what from_frame used
    assert [e.as_dict() for e in decode_events(stream.frame)] == \
        [e.as_dict() for e in events]


def test_event_stream_dataframes():
    pd = pytest.importorskip("pandas")
    stream = _golden_stream()
    df = stream.to_dataframe()
    assert isinstance(df, pd.DataFrame)
    assert len(df) == int(stream.frame.count)
    for nm in stream.frame.names:
        assert nm in df.columns
    ev = stream.events_dataframe()
    assert len(ev) == len(stream.events)


def test_api_simulate_carries_frames():
    from repro import api

    speeds, active = _scenario()
    out = api.simulate(speeds, policies=POLICIES, config=CFG,
                       active=active, telemetry=TelemetryConfig())
    assert out.telemetry is not None and len(out.telemetry) == \
        speeds.shape[0]
    assert EventStream.from_frame(out.telemetry[0]).counts()


# ---------------------------------------------------------------------------
# host-side tracer: spans, first-vs-steady, Chrome trace, bounds
# ---------------------------------------------------------------------------

def test_tracer_spans_and_summary():
    tr = Tracer()
    for i in range(3):
        with tr.span("work", idx=i):
            with tr.span("inner"):
                pass
    recs = tr.records("work")
    assert len(recs) == 3
    assert [r.call_index for r in recs] == [0, 1, 2]
    assert [r.args["idx"] for r in recs] == [0, 1, 2]
    assert tr.records("work", idx=1)[0].call_index == 1
    s = tr.summary()["work"]
    assert s["count"] == 3
    assert s["first_us"] >= 0.0 and s["steady_us"] >= 0.0
    assert s["total_us"] >= s["first_us"]
    inner = tr.records("inner")
    assert len(inner) == 3 and inner[0].call_index == 0


def test_tracer_chrome_trace_valid(tmp_path):
    tr = Tracer()
    with tr.span("outer", label="x"):
        tr.instant("marker", hit=True)
    path = tmp_path / "trace.json"
    trace = tr.write(str(path))
    validate_chrome_trace(trace)
    validate_chrome_trace(json.loads(path.read_text()))
    names = [ev["name"] for ev in trace["traceEvents"]]
    assert "outer" in names and "marker" in names
    by_name = {ev["name"]: ev for ev in trace["traceEvents"]
               if ev["ph"] == "X"}
    assert by_name["outer"]["args"]["label"] == "x"
    assert by_name["marker"]["args"]["hit"] is True
    assert by_name["marker"]["dur"] >= 0.0


def test_tracer_bounded():
    tr = Tracer(max_spans=2)
    for i in range(5):
        with tr.span("s", i=i):
            pass
    assert len(tr.records()) == 2
    assert tr.dropped == 3
    tr.reset()
    assert tr.records() == [] and tr.dropped == 0


def test_traced_decorator_and_disabled_tracer():
    tr = Tracer()

    @tr.traced("api.fake")
    def fn(x):
        return x + 1

    assert fn(1) == 2 and fn(2) == 3
    assert [r.name for r in tr.records()] == ["api.fake", "api.fake"]
    tr.enabled = False
    with tr.span("invisible") as args:
        assert args is None
    assert len(tr.records()) == 2


def test_module_level_span_hits_default_tracer():
    from repro.telemetry import default_tracer, instant

    tracer = default_tracer()
    n0 = len(tracer.records())
    with span("test.adhoc", unit=True):
        instant("test.marker")

    @traced("test.fn")
    def fn():
        return 7

    assert fn() == 7
    names = [r.name for r in tracer.records()[n0:]]
    assert names == ["test.marker", "test.adhoc", "test.fn"]


def test_validate_chrome_trace_rejects_garbage():
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X"}]})
    with pytest.raises(ValueError):
        validate_chrome_trace({})


# ---------------------------------------------------------------------------
# fleet runner: per-bucket stats, reset, AOT spans
# ---------------------------------------------------------------------------

def test_fleet_stats_per_bucket_and_reset():
    speeds, active = _scenario(t=20, n=5)
    fleet = FleetRunner(FleetConfig(t_buckets=(32,), n_buckets=(8,)))
    fleet.simulate(POLICIES, speeds, CFG, active=active)
    st = fleet.stats()
    assert st["cache_misses"] >= 1
    assert st["per_bucket"], st
    (bucket, counters), = list(st["per_bucket"].items())[:1] or [(None, {})]
    assert bucket == "32x8"
    assert counters["misses"] >= 1
    fleet.reset()
    st2 = fleet.stats()
    assert st2["cache_hits"] == st2["cache_misses"] == 0
    assert st2["per_bucket"] == {}
    assert st2["cache_entries"] == st["cache_entries"]  # executables kept
    fleet.simulate(POLICIES, speeds, CFG, active=active)
    st3 = fleet.stats()
    assert st3["cache_misses"] == 0 and st3["cache_hits"] >= 1
    assert st3["per_bucket"]["32x8"]["hits"] >= 1


def test_fleet_emits_aot_spans():
    from repro.telemetry import default_tracer

    tracer = default_tracer()
    n0 = len(tracer.records())
    speeds, active = _scenario(t=10, n=4)
    fleet = FleetRunner(FleetConfig())
    fleet.simulate(("MBFP",), speeds, CFG, active=active)
    names = [r.name for r in tracer.records()[n0:]]
    for required in ("fleet.simulate", "fleet.trace_lower", "fleet.compile",
                     "fleet.dispatch"):
        assert required in names, (required, names)


# ---------------------------------------------------------------------------
# bench regression gate
# ---------------------------------------------------------------------------

def test_bench_diff_gate():
    from benchmarks.bench_diff import (DEFAULT_THRESHOLD, diff,
                                       _direction,
                                       _inject_throughput_regression)

    report = {"kind": "x",
              "timing": {"scenario_steps_per_s": 100.0, "steady_us": 10.0,
                         "speedup_vs_python": 50.0, "compile_us": 1e6,
                         "steps_per_scenario": 32, "violation_frac": 0.25}}
    clean = diff(report, report, DEFAULT_THRESHOLD)
    assert clean["regressions"] == [] and clean["improvements"] == []
    hurt = _inject_throughput_regression(report, factor=0.5)
    res = diff(report, hurt, DEFAULT_THRESHOLD)
    regressed = {name for name, *_ in res["regressions"]}
    assert regressed == {"timing/scenario_steps_per_s", "timing/steady_us",
                         "timing/speedup_vs_python"}
    # compile time, bare counts and SLO metrics never gate
    assert _direction(("timing", "compile_us")) == "info"
    assert _direction(("timing", "steps_per_scenario")) == "info"
    assert _direction(("timing", "violation_frac")) == "info"
    assert _direction(("x", "consumer_seconds")) == "info"
    # an improvement is not a regression
    better = _inject_throughput_regression(report, factor=2.0)
    res = diff(report, better, DEFAULT_THRESHOLD)
    assert res["regressions"] == [] and len(res["improvements"]) == 3


# ---------------------------------------------------------------------------
# custom-counter policies end to end + optional-pandas degradation
# ---------------------------------------------------------------------------

def test_counter_state_flows_into_fleet_sketch():
    """A registered policy carrying ``CounterState`` gets its counters
    recorded as first-class channels all the way through the
    fleet-padded path: frame names, sketch aggregation, histograms."""
    from repro import registry
    from repro.telemetry import CounterState, SketchConfig

    NAME = "TEST_COUNTED"

    @registry.register(NAME, family="reactive", backend="jax",
                       summary="test-only KEDA_LAG wrapper with counters")
    def _build(n, capacity):
        inner = registry.make_policy("KEDA_LAG", n, capacity, backend="jax")

        def init(n_partitions):
            return CounterState(counters=jnp.zeros(2, jnp.float32),
                                inner=inner.init(n_partitions),
                                names=("steps_seen", "scale_ups"))

        def step(speeds, lag, prev, state, active=None):
            args = (speeds, lag, prev, state.inner)
            assign, k, nxt = inner.step(*(args if active is None
                                          else args + (active,)))
            up = (nxt[0] > state.inner[0]).astype(jnp.float32)
            counters = state.counters + jnp.stack([jnp.float32(1.0), up])
            return assign, k, CounterState(counters=counters, inner=nxt,
                                           names=state.names)

        return init, step

    try:
        speeds, active = _scenario(t=20, n=5)
        tele = TelemetryConfig(sketch=SketchConfig(
            hist_channels=("lag_total", "steps_seen")))
        cfg = dataclasses.replace(CFG, telemetry=tele)
        # through the fleet (T padded 20 -> 32, N padded 5 -> 8) ...
        fleet = FleetRunner(FleetConfig(t_buckets=(32,), n_buckets=(8,)))
        res = fleet.simulate((NAME,), speeds, cfg, active=active)
        frame_names = res.telemetry[0].names
        assert frame_names[-2:] == ("steps_seen", "scale_ups")
        ((_, counted),) = res.sketch_summaries(0)
        assert counted.names[-2:] == ("steps_seen", "scale_ups")
        # ... the padded steps stay invisible: steps_seen counts exactly
        # the T real steps, on every aggregator
        t = speeds.shape[1]
        i = counted.channel_index("steps_seen")
        assert counted.count == t
        assert float(counted.vmax[i]) == t
        assert float(counted.vmin[i]) == 1.0
        assert float(counted.mean[i]) == pytest.approx((t + 1) / 2)
        assert counted.quantile(1.0, "steps_seen") == pytest.approx(
            t, abs=counted.edges[1] - counted.edges[0])
        # and the fleet result equals the direct engine bit-for-bit
        direct = simulate_lag(speeds[0], policy=NAME, cfg=cfg,
                              active=active[0])
        got = jax.tree_util.tree_map(lambda a: a[0], res.sketch[0])
        for fld in ("count", "mean", "m2", "vmin", "vmax", "hist"):
            assert np.asarray(getattr(got, fld)).tobytes() == \
                np.asarray(getattr(direct.sketch, fld)).tobytes(), fld
        # mixing counter channel sets in one sweep fails by name, not
        # with a cryptic treedef mismatch
        mixed = dataclasses.replace(CFG, telemetry=TelemetryConfig())
        with pytest.raises(ValueError, match="identical telemetry channels"):
            sweep_lag((NAME, "KEDA_LAG"), speeds, cfg=mixed, active=active)
    finally:
        registry._REGISTRY.pop((NAME, "jax"), None)
        if NAME in registry._ORDER:
            registry._ORDER.remove(NAME)


def test_to_dataframe_degrades_without_pandas(monkeypatch):
    """pandas is optional: the dataframe exporters raise a named
    ImportError pointing at the stdlib path, everything else works."""
    import builtins

    speeds, active = _scenario(t=10, n=4)
    res = simulate_lag(speeds[0], policy="MBFP", cfg=_with_tele(CFG),
                       active=active[0])
    stream = EventStream.from_frame(res.telemetry)

    real_import = builtins.__import__

    def no_pandas(name, *a, **kw):
        if name == "pandas" or name.startswith("pandas."):
            raise ImportError(f"No module named {name!r}")
        return real_import(name, *a, **kw)

    monkeypatch.setattr(builtins, "__import__", no_pandas)
    with pytest.raises(ImportError, match="to_dataframe needs pandas"):
        stream.to_dataframe()
    with pytest.raises(ImportError, match="optional dependency"):
        stream.events_dataframe()
    # the stdlib escape hatches named in the error still work
    assert json.loads(stream.to_json())
